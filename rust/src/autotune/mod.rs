//! Tile-configuration autotuner.
//!
//! The paper reports the best-performing variant over "different
//! combinations of thread block level tiles and warp level tiles" (§4).
//! This module enumerates the same space under the paper's constraints
//! (static 48 KiB shared memory, <=255 registers/thread, tiles dividing the
//! problem, warp tiles dividing thread-block tiles, everything a multiple
//! of the 16^3 WMMA op) and ranks candidates with the performance model.
//!
//! [`sweep_cpu`] is the same search for the executor's CPU micro-kernel
//! engine: it sweeps the cache-block sizes of
//! [`crate::runtime::kernel::KernelPolicy`] by *measurement* (the serving
//! substrate is the host, so wall clock ranks candidates the way the
//! model ranks GPU tiles).
//!
//! [`refine_measured`] is the autotuner in its plan-compiler role: it
//! takes a compiled [`ExecutionPlan`] and lets the plan's kernel compete
//! against alternatives on real wall clock, returning a plan with the
//! winner swapped in — refinement replaces *a variant's plan*
//! (`Registry::refine_plans_measured`), never a process-global policy.

use std::time::Instant;

use crate::plan::{ExecutionPlan, NumericsClass, PassTrace};
use crate::runtime::kernel::{self, Blocking, KernelPolicy};
use crate::runtime::nanokernel;
use crate::schedule::{Dtype, Schedule};
use crate::sim::{simulate, DeviceModel, SimResult};
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct Candidate {
    pub schedule: Schedule,
    pub result: SimResult,
}

/// The tile space explored: thread-block {64,128,256}^2 x k{32,64},
/// warp {32,64}^2 x 32.
pub fn candidate_tiles() -> Vec<((usize, usize, usize), (usize, usize, usize))> {
    let mut out = Vec::new();
    for &tbm in &[64usize, 128, 256] {
        for &tbn in &[64usize, 128, 256] {
            for &tbk in &[32usize, 64] {
                for &wm in &[32usize, 64] {
                    for &wn in &[32usize, 64] {
                        let wk = 32;
                        if tbm % wm != 0 || tbn % wn != 0 || tbk % wk != 0 {
                            continue;
                        }
                        out.push(((tbm, tbn, tbk), (wm, wn, wk)));
                    }
                }
            }
        }
    }
    out
}

/// All feasible candidates for one problem, best first.
pub fn enumerate(
    m: usize,
    n: usize,
    k: usize,
    acc: Dtype,
    device: &DeviceModel,
) -> Vec<Candidate> {
    let mut cands: Vec<Candidate> = candidate_tiles()
        .into_iter()
        .filter_map(|(tb, warp)| {
            let s = Schedule::optimized(m, n, k, acc, tb, warp).ok()?;
            // Paper constraints: static shared memory and register ceiling.
            if s.smem_bytes > device.smem_static_limit {
                return None;
            }
            if s.regs_per_thread() > device.max_regs_per_thread {
                return None;
            }
            if s.threads_per_block > 1024 {
                return None;
            }
            let result = simulate(&s, device);
            Some(Candidate { schedule: s, result })
        })
        .collect();
    cands.sort_by(|a, b| b.result.tflops.partial_cmp(&a.result.tflops).unwrap());
    cands
}

/// One measured CPU kernel configuration.
#[derive(Debug, Clone)]
pub struct CpuCandidate {
    pub policy: KernelPolicy,
    /// Best (minimum) wall time over the timed iterations, seconds.
    pub seconds: f64,
    pub gflops: f64,
}

/// The cache-block space swept on CPU: MC x KC x NC over the plausible
/// L2/L3 budgets, the analog of the paper's thread-block tile grid.
pub fn cpu_blockings() -> Vec<Blocking> {
    let mut out = Vec::new();
    for &mc in &[64usize, 128, 256] {
        for &kc in &[128usize, 256, 512] {
            for &nc in &[256usize, 1024] {
                out.push(Blocking { mc, kc, nc });
            }
        }
    }
    out
}

/// Measure every CPU blocking (plus the naive reference) on an
/// m x n x k problem and rank by GFLOP/s, best first.  `threads == 1`
/// sweeps the single-thread tiled kernel; any other value sweeps the
/// threaded kernel with that thread count (0 = auto).  When the host
/// (or the `MLIR_GEMM_FORCE_ISA` override) offers a nanokernel ISA,
/// every blocking is additionally swept through the `simd:<isa>` kernel
/// — the ISA-aware sweep ranks the `fma_relaxed` candidates against the
/// scalar ones on the same wall clock.  Each candidate gets one warmup
/// plus `iters` timed runs; the minimum counts (the paper's protocol
/// keeps the best-performing variant).
pub fn sweep_cpu(
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    iters: usize,
) -> Vec<CpuCandidate> {
    let mut rng = Rng::new(0xC9);
    let a = rng.normal_matrix(m, k);
    let b = rng.normal_matrix(k, n);
    let mut out = vec![0.0f32; m * n];
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let simd_isa = nanokernel::detect().unwrap_or(None);
    let mut policies = vec![KernelPolicy::Naive];
    for bs in cpu_blockings() {
        policies.push(if threads == 1 {
            KernelPolicy::Tiled(bs)
        } else {
            KernelPolicy::Threaded(bs, threads)
        });
        if let Some(isa) = simd_isa {
            policies.push(KernelPolicy::Simd(bs, if threads == 1 { 1 } else { threads }, isa));
        }
    }
    let mut cands: Vec<CpuCandidate> = policies
        .into_iter()
        .map(|policy| {
            let mut best = f64::INFINITY;
            for it in 0..=iters.max(1) {
                out.fill(0.0);
                let t = Instant::now();
                kernel::matmul(policy, &mut out, &a, &b, m, n, k);
                let dt = t.elapsed().as_secs_f64();
                if it > 0 {
                    best = best.min(dt);
                }
            }
            CpuCandidate { policy, seconds: best, gflops: flops / best.max(1e-12) / 1e9 }
        })
        .collect();
    cands.sort_by(|x, y| y.gflops.partial_cmp(&x.gflops).unwrap());
    cands
}

/// Measured refinement of a compiled execution plan: the plan's lowered
/// kernel competes against the naive and default-tiled alternatives on
/// the plan's real shape (min-of-`iters` wall clock, one warmup), and
/// the fastest kernel wins the plan slot.  The sweep is recorded in the
/// plan's provenance trace; everything else about the plan is preserved.
///
/// Refinement respects the plan's numerics class: SIMD candidates are
/// only entered when the plan is already `fma_relaxed` (the caller opted
/// into FMA numerics at compile time).  The refined plan's class tracks
/// the winning kernel, so refinement may *tighten* `fma_relaxed` back to
/// `bit_exact` (a scalar kernel won) but can never relax a `bit_exact`
/// plan — that would silently void the bitwise contracts pinned on it.
pub fn refine_measured(plan: &ExecutionPlan, iters: usize) -> ExecutionPlan {
    let (m, n, k) = (plan.m, plan.n, plan.k);
    if m == 0 || n == 0 || k == 0 {
        return plan.clone();
    }
    let mut candidates: Vec<KernelPolicy> = Vec::new();
    for c in [
        plan.kernel,
        KernelPolicy::Naive,
        KernelPolicy::Tiled(Blocking::default()),
    ] {
        if !candidates.contains(&c) {
            candidates.push(c);
        }
    }
    if plan.numerics == NumericsClass::FmaRelaxed {
        if let Ok(Some(isa)) = nanokernel::detect() {
            let threads = match plan.kernel {
                KernelPolicy::Threaded(_, t) | KernelPolicy::Simd(_, t, _) => t,
                _ => 1,
            };
            let c = KernelPolicy::Simd(Blocking::default(), threads, isa);
            if !candidates.contains(&c) {
                candidates.push(c);
            }
        }
    }
    let n_candidates = candidates.len();
    let mut rng = Rng::new(0xF1);
    let a = rng.normal_matrix(m, k);
    let b = rng.normal_matrix(k, n);
    let mut out = vec![0.0f32; m * n];
    let mut best = (f64::INFINITY, plan.kernel);
    for policy in candidates {
        let mut t_best = f64::INFINITY;
        for it in 0..=iters.max(1) {
            out.fill(0.0);
            let t = Instant::now();
            kernel::matmul(policy, &mut out, &a, &b, m, n, k);
            let dt = t.elapsed().as_secs_f64();
            if it > 0 {
                t_best = t_best.min(dt);
            }
        }
        if t_best < best.0 {
            best = (t_best, policy);
        }
    }
    let mut refined = plan.clone();
    refined.kernel = best.1;
    // The prepack decision tracks the kernel: a swap to/from the direct
    // kernel flips whether bound weights materialize panels.
    refined.prepack = !matches!(best.1, KernelPolicy::Naive);
    // The numerics class tracks the kernel too.  Because SIMD candidates
    // only enter for fma_relaxed plans, this can tighten the class
    // (scalar won an fma_relaxed plan's sweep) but never relax it.
    refined.numerics = NumericsClass::of(&best.1);
    refined.trace.push(PassTrace {
        pass: "measure-refine".to_string(),
        decision: best.1.name(),
        reason: format!(
            "fastest of {n_candidates} measured kernels at {m}x{n}x{k} \
             (min of {} timed runs each)",
            iters.max(1)
        ),
    });
    refined
}

/// The best candidate, or None when no tile divides the problem.
pub fn best(
    m: usize,
    n: usize,
    k: usize,
    acc: Dtype,
    device: &DeviceModel,
) -> Option<Candidate> {
    enumerate(m, n, k, acc, device).into_iter().next()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d() -> DeviceModel {
        DeviceModel::rtx3090()
    }

    #[test]
    fn space_is_nonempty_and_valid() {
        let tiles = candidate_tiles();
        assert!(tiles.len() >= 20);
        for (tb, warp) in tiles {
            assert_eq!(tb.0 % warp.0, 0);
            assert_eq!(tb.1 % warp.1, 0);
        }
    }

    #[test]
    fn small_problems_choose_small_tiles() {
        // §4.1: "smaller thread block tile sizes like 64x64x64 performed
        // better on smaller problem sizes"
        let c = best(1024, 1024, 1024, Dtype::F32, &d()).unwrap();
        assert!(
            c.schedule.tile_tb.0 * c.schedule.tile_tb.1 <= 128 * 64,
            "picked {:?}",
            c.schedule.tile_tb
        );
    }

    #[test]
    fn large_problems_choose_large_tiles() {
        let c = best(8192, 8192, 8192, Dtype::F32, &d()).unwrap();
        assert!(
            c.schedule.tile_tb.0 * c.schedule.tile_tb.1 >= 128 * 128,
            "picked {:?}",
            c.schedule.tile_tb
        );
    }

    #[test]
    fn all_candidates_respect_smem_limit() {
        for c in enumerate(4096, 4096, 4096, Dtype::F16, &d()) {
            assert!(c.schedule.smem_bytes <= d().smem_static_limit);
            assert!(c.schedule.regs_per_thread() <= 255);
        }
    }

    #[test]
    fn results_sorted_descending() {
        let cands = enumerate(2048, 2048, 2048, Dtype::F32, &d());
        for pair in cands.windows(2) {
            assert!(pair[0].result.tflops >= pair[1].result.tflops);
        }
    }

    #[test]
    fn indivisible_problem_yields_none() {
        assert!(best(100, 100, 100, Dtype::F32, &d()).is_none());
    }

    #[test]
    fn cpu_sweep_measures_and_ranks_every_blocking() {
        let cands = sweep_cpu(48, 48, 48, 1, 1);
        // The sweep is ISA-aware: when the host (or the env override)
        // offers a nanokernel, every blocking appears twice — once
        // scalar, once simd.
        let per_blocking = 1 + nanokernel::detect().unwrap_or(None).is_some() as usize;
        assert_eq!(
            cands.len(),
            cpu_blockings().len() * per_blocking + 1,
            "naive + every blocking (x2 when an ISA is available)"
        );
        assert!(cands.iter().any(|c| c.policy == KernelPolicy::Naive));
        for c in &cands {
            assert!(c.gflops > 0.0 && c.seconds > 0.0, "{c:?}");
        }
        for pair in cands.windows(2) {
            assert!(pair[0].gflops >= pair[1].gflops);
        }
    }

    #[test]
    fn refine_measured_swaps_the_plan_kernel_and_records_the_sweep() {
        use crate::plan::{compile, GemmKey, PlanEnv};
        let plan = compile(&GemmKey::plain(48, 48, 48), &PlanEnv::pinned()).unwrap();
        let refined = refine_measured(&plan, 1);
        // Same contract, refinement only touches the kernel + trace.
        assert_eq!((refined.m, refined.n, refined.k), (plan.m, plan.n, plan.k));
        assert_eq!(refined.epilogue, plan.epilogue);
        assert!(refined.kernel.validate().is_ok());
        assert_eq!(refined.trace.len(), plan.trace.len() + 1);
        assert_eq!(refined.trace.last().unwrap().pass, "measure-refine");
        // Degenerate shapes pass through untouched.
        let zero = compile(&GemmKey::plain(0, 0, 0), &PlanEnv::pinned()).unwrap();
        assert_eq!(refine_measured(&zero, 1), zero);
    }

    #[test]
    fn refinement_never_relaxes_a_bit_exact_plan() {
        use crate::plan::{compile, GemmKey, PlanEnv};
        // A default-compiled plan is bit_exact; refinement must not
        // introduce a SIMD kernel (that would silently change numerics).
        let plan = compile(&GemmKey::plain(48, 48, 48), &PlanEnv::pinned()).unwrap();
        assert_eq!(plan.numerics, NumericsClass::BitExact);
        let refined = refine_measured(&plan, 1);
        assert!(
            !matches!(refined.kernel, KernelPolicy::Simd(..)),
            "bit_exact refinement picked {:?}",
            refined.kernel
        );
        assert_eq!(refined.numerics, NumericsClass::BitExact);
    }

    #[test]
    fn refinement_of_fma_relaxed_tracks_the_winning_kernel_class() {
        use crate::plan::{compile, GemmKey, PlanEnv, PlanOverride};
        let env = PlanEnv::pinned().with_force(PlanOverride::Simd);
        let plan = compile(&GemmKey::plain(48, 48, 48), &env).unwrap();
        assert_eq!(plan.numerics, NumericsClass::FmaRelaxed);
        let refined = refine_measured(&plan, 1);
        // Whatever kernel wins, the recorded class must agree with it.
        assert_eq!(refined.numerics, NumericsClass::of(&refined.kernel));
        assert_eq!(refined.trace.last().unwrap().pass, "measure-refine");
    }

    #[test]
    fn fp16_beats_library_choice_at_11264() {
        // §4.2: at 11264 ours picks a better tile than the library's
        use crate::sim::simulate_library;
        let ours = best(11264, 11264, 11264, Dtype::F16, &d()).unwrap();
        let lib = simulate_library(11264, 11264, 11264, Dtype::F16, &d());
        assert!(
            ours.result.tflops > lib.tflops,
            "ours {} vs lib {}",
            ours.result.tflops,
            lib.tflops
        );
    }
}
