//! Tile-configuration autotuner.
//!
//! The paper reports the best-performing variant over "different
//! combinations of thread block level tiles and warp level tiles" (§4).
//! This module enumerates the same space under the paper's constraints
//! (static 48 KiB shared memory, <=255 registers/thread, tiles dividing the
//! problem, warp tiles dividing thread-block tiles, everything a multiple
//! of the 16^3 WMMA op) and ranks candidates with the performance model.

use crate::schedule::{Dtype, Schedule};
use crate::sim::{simulate, DeviceModel, SimResult};

#[derive(Debug, Clone)]
pub struct Candidate {
    pub schedule: Schedule,
    pub result: SimResult,
}

/// The tile space explored: thread-block {64,128,256}^2 x k{32,64},
/// warp {32,64}^2 x 32.
pub fn candidate_tiles() -> Vec<((usize, usize, usize), (usize, usize, usize))> {
    let mut out = Vec::new();
    for &tbm in &[64usize, 128, 256] {
        for &tbn in &[64usize, 128, 256] {
            for &tbk in &[32usize, 64] {
                for &wm in &[32usize, 64] {
                    for &wn in &[32usize, 64] {
                        let wk = 32;
                        if tbm % wm != 0 || tbn % wn != 0 || tbk % wk != 0 {
                            continue;
                        }
                        out.push(((tbm, tbn, tbk), (wm, wn, wk)));
                    }
                }
            }
        }
    }
    out
}

/// All feasible candidates for one problem, best first.
pub fn enumerate(
    m: usize,
    n: usize,
    k: usize,
    acc: Dtype,
    device: &DeviceModel,
) -> Vec<Candidate> {
    let mut cands: Vec<Candidate> = candidate_tiles()
        .into_iter()
        .filter_map(|(tb, warp)| {
            let s = Schedule::optimized(m, n, k, acc, tb, warp).ok()?;
            // Paper constraints: static shared memory and register ceiling.
            if s.smem_bytes > device.smem_static_limit {
                return None;
            }
            if s.regs_per_thread() > device.max_regs_per_thread {
                return None;
            }
            if s.threads_per_block > 1024 {
                return None;
            }
            let result = simulate(&s, device);
            Some(Candidate { schedule: s, result })
        })
        .collect();
    cands.sort_by(|a, b| b.result.tflops.partial_cmp(&a.result.tflops).unwrap());
    cands
}

/// The best candidate, or None when no tile divides the problem.
pub fn best(
    m: usize,
    n: usize,
    k: usize,
    acc: Dtype,
    device: &DeviceModel,
) -> Option<Candidate> {
    enumerate(m, n, k, acc, device).into_iter().next()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d() -> DeviceModel {
        DeviceModel::rtx3090()
    }

    #[test]
    fn space_is_nonempty_and_valid() {
        let tiles = candidate_tiles();
        assert!(tiles.len() >= 20);
        for (tb, warp) in tiles {
            assert_eq!(tb.0 % warp.0, 0);
            assert_eq!(tb.1 % warp.1, 0);
        }
    }

    #[test]
    fn small_problems_choose_small_tiles() {
        // §4.1: "smaller thread block tile sizes like 64x64x64 performed
        // better on smaller problem sizes"
        let c = best(1024, 1024, 1024, Dtype::F32, &d()).unwrap();
        assert!(
            c.schedule.tile_tb.0 * c.schedule.tile_tb.1 <= 128 * 64,
            "picked {:?}",
            c.schedule.tile_tb
        );
    }

    #[test]
    fn large_problems_choose_large_tiles() {
        let c = best(8192, 8192, 8192, Dtype::F32, &d()).unwrap();
        assert!(
            c.schedule.tile_tb.0 * c.schedule.tile_tb.1 >= 128 * 128,
            "picked {:?}",
            c.schedule.tile_tb
        );
    }

    #[test]
    fn all_candidates_respect_smem_limit() {
        for c in enumerate(4096, 4096, 4096, Dtype::F16, &d()) {
            assert!(c.schedule.smem_bytes <= d().smem_static_limit);
            assert!(c.schedule.regs_per_thread() <= 255);
        }
    }

    #[test]
    fn results_sorted_descending() {
        let cands = enumerate(2048, 2048, 2048, Dtype::F32, &d());
        for pair in cands.windows(2) {
            assert!(pair[0].result.tflops >= pair[1].result.tflops);
        }
    }

    #[test]
    fn indivisible_problem_yields_none() {
        assert!(best(100, 100, 100, Dtype::F32, &d()).is_none());
    }

    #[test]
    fn fp16_beats_library_choice_at_11264() {
        // §4.2: at 11264 ours picks a better tile than the library's
        use crate::sim::simulate_library;
        let ours = best(11264, 11264, 11264, Dtype::F16, &d()).unwrap();
        let lib = simulate_library(11264, 11264, 11264, Dtype::F16, &d());
        assert!(
            ours.result.tflops > lib.tflops,
            "ours {} vs lib {}",
            ours.result.tflops,
            lib.tflops
        );
    }
}
