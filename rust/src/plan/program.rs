//! Graph-level program plans: lower a whole `*.tprog.json` graph, not
//! one GEMM at a time.
//!
//! [`compile_program`] runs four explicit graph passes over a composite
//! program (today: the transformer block) layered on top of the
//! per-GEMM 6-pass pipeline in [`super`]:
//!
//! 1. **op-graph** — extract the program's GEMM ops from the descriptor
//!    and lower each through [`plan::compile`] under the same keys the
//!    per-op hand loop used, so op-level decisions are unchanged.
//! 2. **cast-hoist** — the q/k/v projections consume one shared
//!    `dtype_in`-rounded copy of the activation (the fused
//!    `[d_model × 3·d_model]` QKV weight makes the sharing structural);
//!    the pass records the hoist and the casts it saves.  `round_to` is
//!    deterministic, so one shared cast is bit-identical to three
//!    private ones.
//! 3. **buffer-reuse** — lifetime-packed first-fit assignment of every
//!    intermediate onto a scratch arena ([`ArenaSlot`]); each slot is
//!    zero-filled or fully rewritten before any read, so reuse is
//!    bit-invisible.  The executor's arena reproduces this assignment
//!    by construction: it takes the lowest-indexed free slot in the
//!    same program order the pass walks.
//! 4. **pipeline** — chained-GEMM streaming decisions.  The default is
//!    conservative: every producer→consumer edge is `materialize`d,
//!    because streaming C panels of GEMM1 into packed-A panels of GEMM2
//!    reorders the consumer's A cast against the producer's epilogue
//!    and is not bit-exact.  The decision is recorded in the trace
//!    either way; an opt-in streaming mode carries the `fma_relaxed`
//!    numerics class.
//!
//! A [`ProgramPlan`] is a first-class value like
//! [`ExecutionPlan`](super::ExecutionPlan): JSON round-trippable with
//! per-pass provenance, golden-pinned, compiled at artifact load, cached
//! in the coordinator registry, and honored by both the inline and
//! weight-bound transformer paths.

use anyhow::{anyhow, bail, Result};

use crate::plan::{self, ExecutionPlan, GemmKey, NumericsClass, PassTrace, PlanEnv};
use crate::runtime::exec::Program;
use crate::runtime::KernelPolicy;
use crate::schedule::Dtype;
use crate::util::json::{self, Json};

/// Format tag every serialized program plan carries.
pub const PROGRAM_PLAN_FORMAT: &str = "mlir-gemm-program-plan-v1";

/// One GEMM node of the op graph, with its compiled per-op plan.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramOp {
    /// Role of this GEMM in the graph (`qkv`, `scores`, `ctx`,
    /// `attn_out`, `ffn_up`, `ffn_dn`).
    pub name: String,
    /// Executions per program run (the per-head ops run `n_heads`
    /// times).
    pub count: usize,
    pub plan: ExecutionPlan,
}

/// One hoisted operand cast: `operand` is rounded to `dtype_in` once and
/// shared by every user instead of being re-cast per consumer.
#[derive(Debug, Clone, PartialEq)]
pub struct CastHoist {
    pub operand: String,
    pub users: Vec<String>,
    pub casts_saved: usize,
}

/// One scratch-arena slot and the intermediates that time-share it.
#[derive(Debug, Clone, PartialEq)]
pub struct ArenaSlot {
    pub slot: usize,
    /// High-water element count (the largest buffer assigned here).
    pub elems: usize,
    /// Buffers assigned to this slot, in program order.
    pub buffers: Vec<String>,
}

/// One chained-GEMM edge and its pipelining decision.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineDecision {
    pub producer: String,
    pub consumer: String,
    /// `materialize` (bit-exact default) or `stream` (opt-in, carries
    /// the relaxed numerics class).
    pub mode: String,
}

/// The compiled plan for a whole tensor program.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramPlan {
    /// Program family this plan lowers (`transformer`).
    pub kind: String,
    pub seq: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub dtype_in: Dtype,
    pub ops: Vec<ProgramOp>,
    pub cast_hoists: Vec<CastHoist>,
    pub arena: Vec<ArenaSlot>,
    pub pipeline: Vec<PipelineDecision>,
    /// Worst numerics class across the op plans: `bit_exact` unless an
    /// op lowered to an FMA-contracting SIMD kernel.
    pub numerics: NumericsClass,
    /// Graph-pass provenance (op-graph, cast-hoist, buffer-reuse,
    /// pipeline); per-op 6-pass traces live inside each op's plan.
    pub trace: Vec<PassTrace>,
}

impl ProgramPlan {
    /// Stable identifier for metrics attribution and logs.
    pub fn id(&self) -> String {
        format!(
            "transformer:{}x{}x{}h{}/{}",
            self.seq,
            self.d_model,
            self.d_ff,
            self.n_heads,
            self.dtype_in.name()
        )
    }

    /// ISA rollup label: the shared op label when uniform, `mixed`
    /// when op plans lowered to different backends.
    pub fn isa_label(&self) -> String {
        let first = self
            .ops
            .first()
            .map(|o| o.plan.isa_label())
            .unwrap_or_else(|| "scalar".to_string());
        if self.ops.iter().all(|o| o.plan.isa_label() == first) {
            first
        } else {
            "mixed".to_string()
        }
    }

    /// Total GEMM flops of one program execution (per-head ops counted
    /// `count` times).
    pub fn flops_per_item(&self) -> f64 {
        self.ops
            .iter()
            .map(|o| {
                2.0 * o.plan.m as f64
                    * o.plan.n as f64
                    * o.plan.k as f64
                    * o.count as f64
            })
            .sum()
    }

    pub fn op(&self, name: &str) -> Option<&ProgramOp> {
        self.ops.iter().find(|o| o.name == name)
    }

    /// The compiled plan of a named op; the executor drives every GEMM
    /// through these.
    pub fn op_plan(&self, name: &str) -> Result<&ExecutionPlan> {
        self.op(name)
            .map(|o| &o.plan)
            .ok_or_else(|| anyhow!("program plan has no op {name:?}"))
    }

    /// Whether this plan describes `program` (shape and dtype agree).
    pub fn matches(&self, program: &Program) -> bool {
        matches!(
            *program,
            Program::Transformer { seq, d_model, d_ff, n_heads, dtype_in }
                if seq == self.seq
                    && d_model == self.d_model
                    && d_ff == self.d_ff
                    && n_heads == self.n_heads
                    && dtype_in == self.dtype_in
        )
    }

    pub fn to_json(&self) -> Json {
        let ops: Vec<Json> = self
            .ops
            .iter()
            .map(|o| {
                json::obj(vec![
                    ("name", json::s(&o.name)),
                    ("count", json::num(o.count as f64)),
                    ("plan", o.plan.to_json()),
                ])
            })
            .collect();
        let hoists: Vec<Json> = self
            .cast_hoists
            .iter()
            .map(|h| {
                json::obj(vec![
                    ("operand", json::s(&h.operand)),
                    (
                        "users",
                        Json::Arr(h.users.iter().map(|u| json::s(u)).collect()),
                    ),
                    ("casts_saved", json::num(h.casts_saved as f64)),
                ])
            })
            .collect();
        let arena: Vec<Json> = self
            .arena
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("slot", json::num(s.slot as f64)),
                    ("elems", json::num(s.elems as f64)),
                    (
                        "buffers",
                        Json::Arr(s.buffers.iter().map(|b| json::s(b)).collect()),
                    ),
                ])
            })
            .collect();
        let pipeline: Vec<Json> = self
            .pipeline
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("producer", json::s(&p.producer)),
                    ("consumer", json::s(&p.consumer)),
                    ("mode", json::s(&p.mode)),
                ])
            })
            .collect();
        let trace: Vec<Json> = self
            .trace
            .iter()
            .map(|t| {
                json::obj(vec![
                    ("pass", json::s(&t.pass)),
                    ("decision", json::s(&t.decision)),
                    ("reason", json::s(&t.reason)),
                ])
            })
            .collect();
        json::obj(vec![
            ("format", json::s(PROGRAM_PLAN_FORMAT)),
            ("kind", json::s(&self.kind)),
            ("seq", json::num(self.seq as f64)),
            ("d_model", json::num(self.d_model as f64)),
            ("d_ff", json::num(self.d_ff as f64)),
            ("n_heads", json::num(self.n_heads as f64)),
            ("dtype_in", json::s(self.dtype_in.name())),
            ("numerics", json::s(self.numerics.name())),
            ("ops", Json::Arr(ops)),
            ("cast_hoists", Json::Arr(hoists)),
            ("arena", Json::Arr(arena)),
            ("pipeline", Json::Arr(pipeline)),
            ("trace", Json::Arr(trace)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ProgramPlan> {
        let format = j.get("format").and_then(Json::as_str).unwrap_or("");
        if format != PROGRAM_PLAN_FORMAT {
            bail!(
                "unsupported program-plan format {format:?} (want {PROGRAM_PLAN_FORMAT})"
            );
        }
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("program plan missing \"kind\""))?
            .to_string();
        if kind != "transformer" {
            bail!("unknown program kind {kind:?}");
        }
        let get_u = |f: &str| {
            j.get(f)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("program plan missing usize field {f:?}"))
        };
        let seq = get_u("seq")?;
        let d_model = get_u("d_model")?;
        let d_ff = get_u("d_ff")?;
        let n_heads = get_u("n_heads")?;
        let dtype_in = j
            .get("dtype_in")
            .and_then(Json::as_str)
            .and_then(Dtype::parse)
            .ok_or_else(|| anyhow!("program plan missing/invalid \"dtype_in\""))?;
        let ops_json = j
            .get("ops")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("program plan missing \"ops\""))?;
        let mut ops = Vec::with_capacity(ops_json.len());
        for o in ops_json {
            let name = o
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("program-plan op missing \"name\""))?
                .to_string();
            let count = o
                .get("count")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("program-plan op {name:?} missing \"count\""))?;
            let plan_json = o
                .get("plan")
                .ok_or_else(|| anyhow!("program-plan op {name:?} missing \"plan\""))?;
            let plan = ExecutionPlan::from_json(plan_json)
                .map_err(|e| anyhow!("program-plan op {name:?}: {e}"))?;
            ops.push(ProgramOp { name, count, plan });
        }
        if ops.is_empty() {
            bail!("program plan has no ops");
        }
        let mut cast_hoists = Vec::new();
        for h in j.get("cast_hoists").and_then(Json::as_arr).unwrap_or(&[]) {
            cast_hoists.push(CastHoist {
                operand: h
                    .get("operand")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("cast hoist missing \"operand\""))?
                    .to_string(),
                users: h
                    .get("users")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|u| u.as_str().map(str::to_string))
                    .collect(),
                casts_saved: h
                    .get("casts_saved")
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
            });
        }
        let mut arena = Vec::new();
        for s in j.get("arena").and_then(Json::as_arr).unwrap_or(&[]) {
            arena.push(ArenaSlot {
                slot: s
                    .get("slot")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("arena slot missing \"slot\""))?,
                elems: s
                    .get("elems")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("arena slot missing \"elems\""))?,
                buffers: s
                    .get("buffers")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|b| b.as_str().map(str::to_string))
                    .collect(),
            });
        }
        let mut pipeline = Vec::new();
        for p in j.get("pipeline").and_then(Json::as_arr).unwrap_or(&[]) {
            let field = |f: &str| {
                p.get(f)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("pipeline decision missing {f:?}"))
            };
            let mode = field("mode")?;
            if mode != "materialize" && mode != "stream" {
                bail!("pipeline decision has unknown mode {mode:?}");
            }
            pipeline.push(PipelineDecision {
                producer: field("producer")?,
                consumer: field("consumer")?,
                mode,
            });
        }
        let derived = derive_numerics(&ops);
        let numerics = match j.get("numerics").and_then(Json::as_str) {
            Some(s) => {
                let stated = NumericsClass::parse(s)
                    .ok_or_else(|| anyhow!("unknown numerics class {s:?}"))?;
                if stated != derived {
                    bail!(
                        "program plan states numerics {:?} but its op plans derive {:?}",
                        stated.name(),
                        derived.name()
                    );
                }
                stated
            }
            None => derived,
        };
        let mut trace = Vec::new();
        for t in j.get("trace").and_then(Json::as_arr).unwrap_or(&[]) {
            trace.push(PassTrace {
                pass: t.get("pass").and_then(Json::as_str).unwrap_or("").to_string(),
                decision: t
                    .get("decision")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                reason: t
                    .get("reason")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            });
        }
        Ok(ProgramPlan {
            kind,
            seq,
            d_model,
            d_ff,
            n_heads,
            dtype_in,
            ops,
            cast_hoists,
            arena,
            pipeline,
            numerics,
            trace,
        })
    }

    pub fn from_text(text: &str) -> Result<ProgramPlan> {
        let j = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        ProgramPlan::from_json(&j)
    }

    /// Human-readable graph-pass trace for the CLI (same layout as the
    /// per-GEMM plan trace).
    pub fn render_trace(&self) -> String {
        let mut out = String::new();
        for t in &self.trace {
            out.push_str(&format!("{:<18} {:<36} {}\n", t.pass, t.decision, t.reason));
        }
        out
    }
}

fn derive_numerics(ops: &[ProgramOp]) -> NumericsClass {
    if ops.iter().any(|o| o.plan.numerics == NumericsClass::FmaRelaxed) {
        NumericsClass::FmaRelaxed
    } else {
        NumericsClass::BitExact
    }
}

/// Lower one op through the per-GEMM pipeline under the exact key the
/// transformer hand loop planned with (`epilogue: "none"`, f32
/// accumulate; bias/relu tails are applied by the program executor).
fn compile_op(
    name: &str,
    count: usize,
    m: usize,
    n: usize,
    k: usize,
    dtype_in: Dtype,
    env: &PlanEnv,
) -> ProgramOp {
    let key = GemmKey {
        m,
        n,
        k,
        dtype_in,
        dtype_acc: Dtype::F32,
        epilogue: "none".into(),
    };
    let plan = plan::compile(&key, env).unwrap_or_else(|_| {
        ExecutionPlan::manual(&key, KernelPolicy::Naive, false)
            .expect("the naive plan is always valid")
    });
    ProgramOp { name: name.to_string(), count, plan }
}

/// One intermediate buffer's lifetime over the linearized program
/// schedule: live on `[birth, death]` inclusive.
struct BufSpec {
    name: &'static str,
    elems: usize,
    birth: usize,
    death: usize,
}

/// The transformer's intermediates in program (= birth) order, over the
/// linear schedule the executor walks:
///
/// ```text
///  0 x cast        4 attn_out GEMM    8 ffn_up GEMM (+bias relu)
///  1 qkv GEMM      5 residual add     9 up cast
///  2 head loop     6 layernorm       10 ffn_dn GEMM (+bias)
///  3 ctx cast      7 hn cast         11 output residual
/// ```
///
/// Cast buffers exist only when `dtype_in != f32` (f32 activations are
/// borrowed uncast).  The output buffer (`dn`) is excluded: it is
/// returned, not scratch.
fn transformer_buffers(
    seq: usize,
    d_model: usize,
    d_ff: usize,
    n_heads: usize,
    cast: bool,
) -> Vec<BufSpec> {
    let d_head = d_model / n_heads;
    let mut bufs = Vec::new();
    let mut push = |name, elems, birth, death| {
        bufs.push(BufSpec { name, elems, birth, death });
    };
    if cast {
        push("x_cast", seq * d_model, 0, 1);
    }
    push("qkv", seq * 3 * d_model, 1, 2);
    push("q_head", seq * d_head, 2, 2);
    push("kt_head", d_head * seq, 2, 2);
    push("v_head", seq * d_head, 2, 2);
    push("scores", seq * seq, 2, 2);
    push("ctx_head", seq * d_head, 2, 2);
    push("denom", seq, 2, 2);
    push("ctx", seq * d_model, 2, 4);
    if cast {
        push("ctx_cast", seq * d_model, 3, 4);
    }
    push("attn_out", seq * d_model, 4, 5);
    push("h_res", seq * d_model, 5, 11);
    push("hn", seq * d_model, 6, 8);
    if cast {
        push("hn_cast", seq * d_model, 7, 8);
    }
    push("up", seq * d_ff, 8, 10);
    if cast {
        push("up_cast", seq * d_ff, 9, 10);
    }
    bufs
}

/// First-fit interval packing: walk buffers in birth order, reuse the
/// lowest-indexed slot whose last occupant died before this birth.  The
/// executor's arena performs the same first-free-slot scan at run time,
/// so this assignment is what actually executes.
fn arena_assign(bufs: &[BufSpec]) -> Vec<ArenaSlot> {
    let mut slots: Vec<(usize, usize, Vec<String>)> = Vec::new();
    for b in bufs {
        match slots.iter_mut().find(|(last_death, _, _)| *last_death < b.birth) {
            Some(slot) => {
                slot.0 = b.death;
                slot.1 = slot.1.max(b.elems);
                slot.2.push(b.name.to_string());
            }
            None => slots.push((b.death, b.elems, vec![b.name.to_string()])),
        }
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(slot, (_, elems, buffers))| ArenaSlot { slot, elems, buffers })
        .collect()
}

/// Compile a whole-program plan.  Per-GEMM programs compile an
/// [`ExecutionPlan`](super::ExecutionPlan) instead and are rejected
/// here.
pub fn compile_program(program: &Program, env: &PlanEnv) -> Result<ProgramPlan> {
    let (seq, d_model, d_ff, n_heads, dtype_in) = match *program {
        Program::Transformer { seq, d_model, d_ff, n_heads, dtype_in } => {
            (seq, d_model, d_ff, n_heads, dtype_in)
        }
        Program::Gemm { .. } => {
            bail!("gemm programs compile a per-GEMM ExecutionPlan, not a ProgramPlan")
        }
    };
    if n_heads == 0 || d_model % n_heads != 0 {
        bail!("transformer d_model {d_model} is not divisible by n_heads {n_heads}");
    }
    let d_head = d_model / n_heads;
    let d3 = 3 * d_model;
    let mut trace = Vec::new();

    // Pass 1: op-graph extraction + per-op lowering.
    let ops = vec![
        compile_op("qkv", 1, seq, d3, d_model, dtype_in, env),
        compile_op("scores", n_heads, seq, seq, d_head, Dtype::F32, env),
        compile_op("ctx", n_heads, seq, d_head, seq, Dtype::F32, env),
        compile_op("attn_out", 1, seq, d_model, d_model, dtype_in, env),
        compile_op("ffn_up", 1, seq, d_ff, d_model, dtype_in, env),
        compile_op("ffn_dn", 1, seq, d_model, d_ff, dtype_in, env),
    ];
    trace.push(PassTrace {
        pass: "op-graph".into(),
        decision: format!("{} ops / {} gemm executions", ops.len(), 4 + 2 * n_heads),
        reason: format!(
            "transformer seq={seq} d_model={d_model} d_ff={d_ff} heads={n_heads}; \
             per-op plans from the 6-pass gemm pipeline"
        ),
    });

    // Pass 2: cast hoisting.
    let cast = dtype_in != Dtype::F32;
    let cast_hoists = if cast {
        vec![CastHoist {
            operand: "x".into(),
            users: vec!["q".into(), "k".into(), "v".into()],
            casts_saved: 2,
        }]
    } else {
        Vec::new()
    };
    trace.push(PassTrace {
        pass: "cast-hoist".into(),
        decision: if cast {
            "1 shared x cast feeds q/k/v (2 saved)".into()
        } else {
            "no-op".into()
        },
        reason: if cast {
            "w_qkv is one fused [d_model x 3*d_model] weight, so the three \
             projections read a single dtype_in-rounded activation; round_to \
             is deterministic, making the shared cast bit-identical to three \
             private ones"
                .into()
        } else {
            "f32 activations are borrowed uncast".into()
        },
    });

    // Pass 3: inter-op buffer reuse.
    let bufs = transformer_buffers(seq, d_model, d_ff, n_heads, cast);
    let arena = arena_assign(&bufs);
    let buf_elems: usize = bufs.iter().map(|b| b.elems).sum();
    let slot_elems: usize = arena.iter().map(|s| s.elems).sum();
    let saved_bytes = 4 * (buf_elems - slot_elems);
    trace.push(PassTrace {
        pass: "buffer-reuse".into(),
        decision: format!(
            "{} buffers -> {} arena slots ({saved_bytes} B saved)",
            bufs.len(),
            arena.len()
        ),
        reason: "lifetime-packed first-fit over the linear schedule; every slot \
                 is zero-filled or fully rewritten before reads, so reuse is \
                 bit-invisible"
            .into(),
    });

    // Pass 4: chained-GEMM pipelining.
    let edge = |producer: &str, consumer: &str| PipelineDecision {
        producer: producer.to_string(),
        consumer: consumer.to_string(),
        mode: "materialize".to_string(),
    };
    let pipeline = vec![
        edge("qkv", "scores"),
        edge("scores", "ctx"),
        edge("ctx", "attn_out"),
        edge("ffn_up", "ffn_dn"),
    ];
    trace.push(PassTrace {
        pass: "pipeline".into(),
        decision: format!("materialize all {} chained-gemm edges", pipeline.len()),
        reason: "conservative default: streaming producer C panels into consumer \
                 packed-A panels reorders the consumer's A cast against the \
                 producer's epilogue and is not bit-exact; opt-in streaming \
                 carries the fma_relaxed class"
            .into(),
    });

    let numerics = derive_numerics(&ops);
    Ok(ProgramPlan {
        kind: "transformer".into(),
        seq,
        d_model,
        d_ff,
        n_heads,
        dtype_in,
        ops,
        cast_hoists,
        arena,
        pipeline,
        numerics,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PlanOverride;

    fn tf(dtype_in: Dtype) -> Program {
        Program::Transformer { seq: 8, d_model: 16, d_ff: 32, n_heads: 4, dtype_in }
    }

    #[test]
    fn compiles_the_standard_transformer() {
        let pp = compile_program(&tf(Dtype::F16), &PlanEnv::pinned()).unwrap();
        assert_eq!(pp.kind, "transformer");
        assert_eq!(
            pp.ops.iter().map(|o| o.name.as_str()).collect::<Vec<_>>(),
            ["qkv", "scores", "ctx", "attn_out", "ffn_up", "ffn_dn"]
        );
        // Per-head ops run once per head.
        assert_eq!(pp.op("scores").unwrap().count, 4);
        assert_eq!(pp.op("ctx").unwrap().count, 4);
        // Op keys are the hand loop's: qkv is seq x 3*d_model x d_model.
        let qkv = &pp.op("qkv").unwrap().plan;
        assert_eq!((qkv.m, qkv.n, qkv.k), (8, 48, 16));
        assert_eq!(qkv.dtype_in, Dtype::F16);
        // Attention internals stay f32 (post-cast activations).
        assert_eq!(pp.op("scores").unwrap().plan.dtype_in, Dtype::F32);
        assert_eq!(pp.numerics, NumericsClass::BitExact);
        assert_eq!(
            pp.trace.iter().map(|t| t.pass.as_str()).collect::<Vec<_>>(),
            ["op-graph", "cast-hoist", "buffer-reuse", "pipeline"]
        );
        assert_eq!(pp.id(), "transformer:8x16x32h4/f16");
        assert!(pp.matches(&tf(Dtype::F16)));
        assert!(!pp.matches(&tf(Dtype::F32)));
        let flops = 2.0
            * ((8 * 48 * 16) + (8 * 16 * 16) + (8 * 32 * 16) + (8 * 16 * 32)
                + 4 * (8 * 8 * 4) + 4 * (8 * 4 * 8)) as f64;
        assert_eq!(pp.flops_per_item(), flops);
    }

    #[test]
    fn cast_hoist_saves_two_casts_for_f16_and_none_for_f32() {
        let f16 = compile_program(&tf(Dtype::F16), &PlanEnv::pinned()).unwrap();
        assert_eq!(f16.cast_hoists.len(), 1);
        assert_eq!(f16.cast_hoists[0].operand, "x");
        assert_eq!(f16.cast_hoists[0].users, ["q", "k", "v"]);
        assert_eq!(f16.cast_hoists[0].casts_saved, 2);
        let f32p = compile_program(&tf(Dtype::F32), &PlanEnv::pinned()).unwrap();
        assert!(f32p.cast_hoists.is_empty());
    }

    #[test]
    fn arena_packs_intermediates_into_fewer_slots() {
        let pp = compile_program(&tf(Dtype::F16), &PlanEnv::pinned()).unwrap();
        let buffers: Vec<&str> = pp
            .arena
            .iter()
            .flat_map(|s| s.buffers.iter().map(String::as_str))
            .collect();
        // Every intermediate is assigned exactly once.
        assert_eq!(buffers.len(), 16);
        for name in [
            "x_cast", "qkv", "q_head", "kt_head", "v_head", "scores", "ctx_head",
            "denom", "ctx", "ctx_cast", "attn_out", "h_res", "hn", "hn_cast",
            "up", "up_cast",
        ] {
            assert_eq!(
                buffers.iter().filter(|b| **b == name).count(),
                1,
                "{name} should be assigned to exactly one slot"
            );
        }
        // Reuse actually happens: fewer slots than buffers.
        assert!(pp.arena.len() < buffers.len());
        // Slots are disjoint in time: within a slot, each buffer's birth
        // follows the previous one's death (guaranteed by construction —
        // pinned here so a refactor can't silently break it).
        assert_eq!(pp.arena.len(), 8);
        // The big QKV intermediate's slot is time-shared after the head
        // loop frees it.
        let qkv_slot = pp
            .arena
            .iter()
            .find(|s| s.buffers.iter().any(|b| b == "qkv"))
            .unwrap();
        assert!(qkv_slot.buffers.len() > 1);
        assert_eq!(qkv_slot.elems, 8 * 48);
    }

    #[test]
    fn pipeline_defaults_to_materialize_everywhere() {
        let pp = compile_program(&tf(Dtype::F16), &PlanEnv::pinned()).unwrap();
        assert_eq!(pp.pipeline.len(), 4);
        assert!(pp.pipeline.iter().all(|p| p.mode == "materialize"));
    }

    #[test]
    fn round_trips_through_json() {
        for dtype in [Dtype::F16, Dtype::F32] {
            let pp = compile_program(&tf(dtype), &PlanEnv::pinned()).unwrap();
            let text = pp.to_json().to_string();
            let back = ProgramPlan::from_text(&text).unwrap();
            assert_eq!(pp, back);
        }
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        let pp = compile_program(&tf(Dtype::F16), &PlanEnv::pinned()).unwrap();
        // Wrong format tag.
        let mut j = pp.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("format".into(), json::s("bogus"));
        }
        assert!(ProgramPlan::from_json(&j).is_err());
        // Inconsistent stated numerics.
        let mut j = pp.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("numerics".into(), json::s("fma_relaxed"));
        }
        assert!(ProgramPlan::from_json(&j).is_err());
        // Unknown pipeline mode.
        let mut j = pp.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert(
                "pipeline".into(),
                Json::Arr(vec![json::obj(vec![
                    ("producer", json::s("a")),
                    ("consumer", json::s("b")),
                    ("mode", json::s("teleport")),
                ])]),
            );
        }
        assert!(ProgramPlan::from_json(&j).is_err());
    }

    #[test]
    fn simd_op_plans_relax_the_program_numerics() {
        let env = PlanEnv::pinned().with_force(PlanOverride::Simd);
        let pp = compile_program(&tf(Dtype::F16), &env).unwrap();
        assert_eq!(pp.numerics, NumericsClass::FmaRelaxed);
        // And round-trips with the relaxed class stated.
        let back = ProgramPlan::from_text(&pp.to_json().to_string()).unwrap();
        assert_eq!(back.numerics, NumericsClass::FmaRelaxed);
    }

    #[test]
    fn rejects_gemm_programs_and_bad_head_counts() {
        let gemm = Program::Gemm {
            m: 4,
            n: 4,
            k: 4,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: crate::runtime::Epilogue::None,
            fused: true,
        };
        assert!(compile_program(&gemm, &PlanEnv::pinned()).is_err());
        let bad = Program::Transformer {
            seq: 8,
            d_model: 16,
            d_ff: 32,
            n_heads: 3,
            dtype_in: Dtype::F16,
        };
        assert!(compile_program(&bad, &PlanEnv::pinned()).is_err());
    }
}
