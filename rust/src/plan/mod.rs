//! Execution-plan compiler: lowers a [`GemmKey`] through an explicit
//! pass pipeline into an [`ExecutionPlan`].
//!
//! The paper's central argument (§3) is that one GEMM should be produced
//! by a *sequence of lowering passes over a single IR* — tile selection,
//! memory staging, thread mapping, epilogue fusion — instead of ad-hoc
//! hand tuning.  The executor used to invert that: a process-global
//! mutable `KernelPolicy` picked one blocking for every variant in the
//! registry.  This module restores the paper's shape on the host side:
//!
//! | pass                 | paper §3 lowering step            | decision                     |
//! |----------------------|-----------------------------------|------------------------------|
//! | tile selection       | thread-block/warp tile choice     | cache [`Blocking`] MCxKCxNC  |
//! | packing              | global -> shared memory staging   | packed panels vs direct loop |
//! | thread partitioning  | grid mapping                      | row-band count               |
//! | epilogue attachment  | epilogue fusion (Table 1 col 4)   | fuse bias+activation into the kernel's write-back |
//! | prepack              | bind-time operand staging         | materialize B panels at weight-bind |
//! | isa                  | warp tile -> `mma.sync` lowering  | `scalar` or `simd:<isa>` micro kernel + numerics class |
//!
//! (See docs/PLAN_SCHEMA.md for the field-by-field JSON reference.)
//! The result is an [`ExecutionPlan`]: an inspectable value (JSON
//! round-trippable, with a per-pass provenance trace) cached per
//! [`GemmKey`] in `coordinator::registry` and threaded *explicitly*
//! through every execution path.  There is no global state anywhere in
//! this module.
//!
//! **Numerics classes.**  Every plan carries a [`NumericsClass`]:
//!
//! * `bit_exact` — the lowered kernel is bit-identical to the naive
//!   i-k-j loop (the `runtime::kernel` module invariant), and the fused
//!   epilogue is applied exactly once per output element *after* that
//!   element's full k-reduction (per disjoint row band, in the band's
//!   own thread), which is the same per-element operation sequence as a
//!   separate epilogue pass.  Sharding's epilogue-replay contract is
//!   untouched because shard programs carry no epilogue and the
//!   reduction replays the tail.  Pinned by
//!   `rust/tests/kernel_equivalence.rs` and the fuzz-differential sweep
//!   across compiled plans.  The pipeline compiles `bit_exact` plans
//!   unless SIMD is explicitly requested — pass 6 keeps the scalar
//!   micro kernel by default so the serving path's bitwise contracts
//!   hold without opt-in.
//! * `fma_relaxed` — pass 6 lowered the register tile to an
//!   explicit-SIMD nanokernel (`runtime::nanokernel`): same
//!   increasing-k term order, but each term contracted with a fused
//!   multiply-add, so the output is verified against the naive oracle
//!   by the condition-scaled ULP-tolerance contract
//!   (`nanokernel::verify_fma_relaxed`, DESIGN.md §10) instead of by
//!   bits.  Requested with `--plan simd` ([`PlanOverride::Simd`]) or a
//!   forced `simd:<isa>` policy; refinement may *tighten* a plan's
//!   class (fma_relaxed -> bit_exact) but never silently relax it.

pub mod program;

use anyhow::{anyhow, bail, Result};

use crate::runtime::kernel::{self, Blocking, BOperand, KernelPolicy, MR, PrepackedB};
use crate::runtime::nanokernel::{self, Isa};
use crate::schedule::Dtype;
use crate::util::json::{self, Json};

/// Format tag for serialized plans.
pub const PLAN_FORMAT: &str = "mlir-gemm-plan-v1";

/// Routing/compilation key for a GEMM: the problem the plan is compiled
/// for.  (Moved here from `coordinator::registry`, which re-exports it:
/// the key is the *input* of the plan compiler, the registry is just one
/// cache of its outputs.)
///
/// `dtype_in` is part of the key: an f16-input kernel and a tf32/f32-input
/// kernel at the same (m, n, k, dtype_acc, epilogue) are different
/// precision modes (§2.3 of the paper) and must never share a variant
/// list or a plan.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GemmKey {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype_in: Dtype,
    pub dtype_acc: Dtype,
    pub epilogue: String,
}

impl GemmKey {
    /// The pipeline's common mode: f16 inputs, f32 accumulate, no epilogue.
    pub fn plain(m: usize, n: usize, k: usize) -> GemmKey {
        GemmKey {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: "none".into(),
        }
    }

    pub fn with_dtypes(
        m: usize,
        n: usize,
        k: usize,
        dtype_in: Dtype,
        dtype_acc: Dtype,
    ) -> GemmKey {
        GemmKey {
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue: "none".into(),
        }
    }
}

/// Operator-facing plan override (`--plan` CLI flag): `auto` runs the
/// full pass pipeline; `simd` runs the same pipeline but asks pass 6 to
/// lower the register tile to a nanokernel (the ISA itself still comes
/// from detection / [`IsaPref`]); anything else forces the lowered
/// kernel while the pipeline still records *why* in the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanOverride {
    Auto,
    /// Full pipeline + SIMD lowering in pass 6 (`--plan simd`).  The
    /// compiled plan is classed `fma_relaxed` unless the scalar
    /// fallback is forced (env/[`IsaPref::Scalar`]).
    Simd,
    Force(KernelPolicy),
}

impl PlanOverride {
    /// `auto` | `simd` | `naive` | `tiled[:MC,KC,NC]` |
    /// `threaded[:MC,KC,NC[,T]]` | `simd:<isa>[:MC,KC,NC[,T]]`.
    pub fn parse(text: &str) -> Result<PlanOverride> {
        if text == "auto" {
            return Ok(PlanOverride::Auto);
        }
        if text == "simd" {
            return Ok(PlanOverride::Simd);
        }
        let policy = KernelPolicy::parse(text)?;
        Ok(PlanOverride::Force(policy))
    }

    pub fn name(&self) -> String {
        match self {
            PlanOverride::Auto => "auto".to_string(),
            PlanOverride::Simd => "simd".to_string(),
            PlanOverride::Force(p) => p.name(),
        }
    }
}

/// How pass 6 resolves the nanokernel ISA when SIMD is requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsaPref {
    /// Probe the host ([`nanokernel::detect`], which also honors the
    /// `MLIR_GEMM_FORCE_ISA` env override).  The production default.
    Detect,
    /// Keep the scalar micro kernel even when SIMD is requested; the
    /// plan stays `bit_exact`.
    Scalar,
    /// Pin the ISA without probing — golden/pinned environments use
    /// this so compiled plans are identical on every build host.
    Fixed(Isa),
}

/// Everything the pass pipeline may consult about the execution
/// substrate: a tiny host-side [`crate::sim::DeviceModel`] analog.  All
/// fields are explicit so compilation is deterministic and testable; the
/// one environmental probe (hardware thread count) is pinned by setting
/// `hw_threads > 0`.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanEnv {
    /// Hardware threads; 0 = detect with `available_parallelism`.
    pub hw_threads: usize,
    /// Executor threads already sharing this host (the server's worker
    /// pool).  Above 1 the thread-partitioning pass picks one band:
    /// intra-GEMM threading under a busy pool oversubscribes the host.
    pub pool_threads: usize,
    /// Cache budget consulted by tile selection and the packing decision.
    pub l2_bytes: usize,
    pub l3_bytes: usize,
    /// `--plan` override; `Auto` runs the full pipeline.
    pub force: PlanOverride,
    /// How pass 6 picks the nanokernel ISA when SIMD is requested.
    pub isa: IsaPref,
}

impl Default for PlanEnv {
    fn default() -> Self {
        PlanEnv {
            hw_threads: 0,
            pool_threads: 1,
            // Generic x86 budget, matching DEFAULT_BLOCKING's sizing
            // logic (A panel L2-resident, B panel L3-resident).
            l2_bytes: 256 * 1024,
            l3_bytes: 8 * 1024 * 1024,
            force: PlanOverride::Auto,
            isa: IsaPref::Detect,
        }
    }
}

impl PlanEnv {
    /// Fully deterministic environment (4 hw threads, default caches,
    /// ISA pinned to avx2 — no host probe): used by the golden-plan
    /// tests so compiled decisions are stable across build hosts.
    /// (Execution of such a plan on a non-AVX2 host still works: the
    /// dispatch layer degrades the body to portable, bits change only
    /// within the fma_relaxed tolerance.)
    pub fn pinned() -> PlanEnv {
        PlanEnv { hw_threads: 4, isa: IsaPref::Fixed(Isa::Avx2Fma), ..Default::default() }
    }

    /// Environment for an executor embedded in a worker pool of
    /// `pool_threads` threads (the server).
    pub fn for_pool(pool_threads: usize) -> PlanEnv {
        PlanEnv { pool_threads: pool_threads.max(1), ..Default::default() }
    }

    pub fn with_force(mut self, force: PlanOverride) -> PlanEnv {
        self.force = force;
        self
    }

    pub fn with_isa(mut self, isa: IsaPref) -> PlanEnv {
        self.isa = isa;
        self
    }

    fn resolved_hw(&self) -> usize {
        if self.hw_threads > 0 {
            self.hw_threads
        } else {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        }
    }
}

/// The numerics contract a compiled plan promises (see the module doc
/// and DESIGN.md §10).  A pure function of the lowered kernel
/// ([`NumericsClass::of`]): scalar kernels are `bit_exact`, SIMD
/// kernels `fma_relaxed`.  Serialized plans carry it explicitly so the
/// contract is visible without knowing the kernel-name grammar; an
/// inconsistent pair is a deserialization error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumericsClass {
    /// Output bit-identical to the naive i-k-j oracle (the
    /// `runtime::kernel` module invariant).
    BitExact,
    /// Output within the condition-scaled FMA tolerance of the oracle
    /// (`runtime::nanokernel::verify_fma_relaxed`).
    FmaRelaxed,
}

impl NumericsClass {
    pub fn name(&self) -> &'static str {
        match self {
            NumericsClass::BitExact => "bit_exact",
            NumericsClass::FmaRelaxed => "fma_relaxed",
        }
    }

    pub fn parse(text: &str) -> Result<NumericsClass> {
        match text {
            "bit_exact" => Ok(NumericsClass::BitExact),
            "fma_relaxed" => Ok(NumericsClass::FmaRelaxed),
            _ => bail!("unknown numerics class {text:?} (bit_exact | fma_relaxed)"),
        }
    }

    /// The class a kernel policy implies.
    pub fn of(kernel: &KernelPolicy) -> NumericsClass {
        match kernel {
            KernelPolicy::Simd(..) => NumericsClass::FmaRelaxed,
            _ => NumericsClass::BitExact,
        }
    }
}

/// One pass's record in the plan's provenance trace.
#[derive(Debug, Clone, PartialEq)]
pub struct PassTrace {
    pub pass: String,
    pub decision: String,
    pub reason: String,
}

fn trace(pass: &str, decision: String, reason: String) -> PassTrace {
    PassTrace { pass: pass.to_string(), decision, reason }
}

/// A compiled execution plan: the complete "how should this GEMM run"
/// decision as one inspectable value.  Replaces the process-global
/// `KernelPolicy` — every execution path receives its plan explicitly.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype_in: Dtype,
    pub dtype_acc: Dtype,
    pub epilogue: String,
    /// The lowered kernel selector (naive / tiled / threaded + blocking).
    pub kernel: KernelPolicy,
    /// Apply the epilogue inside the kernel's per-band write-back instead
    /// of a separate whole-matrix pass.  Bit-identical either way (once
    /// per element, after the full k-reduction); `false` also covers the
    /// deliberately-unfused Table 1 comparator.
    pub fuse_epilogue: bool,
    /// Materialize a bound (constant) B into kernel panel layout once at
    /// bind time ([`ExecutionPlan::prepack_b`]) instead of re-running
    /// `pack_b` per call.  Pass 5's decision; true exactly when the
    /// lowered kernel packs B.  Packing is a pure i/j rearrangement, so
    /// prepacked execution is bit-identical to packing per call.
    pub prepack: bool,
    /// Pass 6's contract: `bit_exact` plans are verified bitwise
    /// against the naive oracle, `fma_relaxed` plans by the
    /// condition-scaled tolerance.  Always equal to
    /// `NumericsClass::of(&self.kernel)` — stored (and serialized)
    /// explicitly so the promise is inspectable and pinned.
    pub numerics: NumericsClass,
    /// Coarse host cost estimate (the `mlir-gemm plan` command prints it
    /// next to a measurement).
    pub predicted_seconds: f64,
    /// Per-pass provenance: what each pass decided and why.
    pub trace: Vec<PassTrace>,
}

impl ExecutionPlan {
    /// The key this plan was compiled for.
    pub fn key(&self) -> GemmKey {
        GemmKey {
            m: self.m,
            n: self.n,
            k: self.k,
            dtype_in: self.dtype_in,
            dtype_acc: self.dtype_acc,
            epilogue: self.epilogue.clone(),
        }
    }

    /// Stable id for metrics attribution (`plan <id>:` report lines).
    /// Includes every key field — two distinct plans (different dtypes or
    /// epilogues at the same shape) must never share an id, or per-plan
    /// metrics would blend them under one label.
    pub fn id(&self) -> String {
        let epi = if self.epilogue == "none" {
            String::new()
        } else {
            format!("+{}", self.epilogue)
        };
        format!(
            "{}x{}x{}/{}->{}:{}{}",
            self.m,
            self.n,
            self.k,
            self.dtype_in.name(),
            self.dtype_acc.name(),
            self.kernel.name(),
            epi
        )
    }

    /// The metrics/reporting label of pass 6's decision: `"scalar"` for
    /// the bit-exact micro kernel, `"simd:<isa>"` for a nanokernel.
    pub fn isa_label(&self) -> String {
        match self.kernel {
            KernelPolicy::Simd(_, _, isa) => format!("simd:{}", isa.name()),
            _ => "scalar".to_string(),
        }
    }

    /// Does this plan describe the given GEMM contract?  Execution paths
    /// check this before running so a mis-threaded plan is an explicit
    /// error, never silent cross-contamination.
    pub fn matches_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        dtype_in: Dtype,
        dtype_acc: Dtype,
        epilogue: &str,
    ) -> bool {
        self.m == m
            && self.n == n
            && self.k == k
            && self.dtype_in == dtype_in
            && self.dtype_acc == dtype_acc
            && self.epilogue == epilogue
    }

    /// Hand-built plan (tests, overrides).  Validates the kernel's
    /// blocking so an invalid tile errors here instead of misbehaving
    /// downstream.
    pub fn manual(key: &GemmKey, kernel: KernelPolicy, fuse_epilogue: bool) -> Result<ExecutionPlan> {
        kernel.validate()?;
        Ok(ExecutionPlan {
            m: key.m,
            n: key.n,
            k: key.k,
            dtype_in: key.dtype_in,
            dtype_acc: key.dtype_acc,
            epilogue: key.epilogue.clone(),
            kernel,
            fuse_epilogue,
            prepack: !matches!(kernel, KernelPolicy::Naive),
            numerics: NumericsClass::of(&kernel),
            predicted_seconds: predict_seconds(key, &kernel),
            trace: vec![trace(
                "manual",
                kernel.name(),
                "plan constructed directly, pass pipeline bypassed".into(),
            )],
        })
    }

    /// `out += A @ B` under this plan's lowered kernel (bit-identical to
    /// the naive loop whatever the plan says).
    pub fn matmul(&self, out: &mut [f32], a: &[f32], b: &[f32]) {
        kernel::matmul(self.kernel, out, a, b, self.m, self.n, self.k);
    }

    /// `out += A @ B`, then `tail` applied to each disjoint row band in
    /// the band's own thread, immediately after that band's k-reduction
    /// completes — the fused-epilogue write-back.
    pub fn matmul_fused(
        &self,
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        tail: &(dyn Fn(&mut [f32]) + Sync),
    ) {
        kernel::matmul_fused(self.kernel, out, a, b, self.m, self.n, self.k, tail);
    }

    /// [`ExecutionPlan::matmul`] over an explicit [`BOperand`] — the
    /// weight-bound hot path hands the bind-time panels through here.
    pub fn matmul_b(&self, out: &mut [f32], a: &[f32], b: BOperand) {
        kernel::matmul_b(self.kernel, out, a, b, self.m, self.n, self.k);
    }

    /// [`ExecutionPlan::matmul_fused`] over an explicit [`BOperand`].
    pub fn matmul_fused_b(
        &self,
        out: &mut [f32],
        a: &[f32],
        b: BOperand,
        tail: &(dyn Fn(&mut [f32]) + Sync),
    ) {
        kernel::matmul_fused_b(self.kernel, out, a, b, self.m, self.n, self.k, tail);
    }

    /// Materialize a constant B into panel layout for this plan's
    /// kernel, or `None` when the prepack pass decided against it (the
    /// direct kernel streams B unpacked, so panels would be dead
    /// weight).  `b` must already carry the plan's `dtype_in` rounding —
    /// callers cast once at bind time, exactly like the per-call path
    /// casts before packing, so the panel bits match packing per call.
    pub fn prepack_b(&self, b: &[f32]) -> Option<PrepackedB> {
        if !self.prepack {
            return None;
        }
        match self.kernel {
            KernelPolicy::Naive => None,
            KernelPolicy::Tiled(bs)
            | KernelPolicy::Threaded(bs, _)
            | KernelPolicy::Simd(bs, _, _) => {
                Some(PrepackedB::pack(b, self.k, self.n, bs))
            }
        }
    }

    // -- JSON (inspectability contract) ---------------------------------

    pub fn to_json(&self) -> Json {
        let trace: Vec<Json> = self
            .trace
            .iter()
            .map(|t| {
                json::obj(vec![
                    ("pass", json::s(&t.pass)),
                    ("decision", json::s(&t.decision)),
                    ("reason", json::s(&t.reason)),
                ])
            })
            .collect();
        json::obj(vec![
            ("format", json::s(PLAN_FORMAT)),
            ("m", json::num(self.m as f64)),
            ("n", json::num(self.n as f64)),
            ("k", json::num(self.k as f64)),
            ("dtype_in", json::s(self.dtype_in.name())),
            ("dtype_acc", json::s(self.dtype_acc.name())),
            ("epilogue", json::s(&self.epilogue)),
            ("kernel", json::s(&self.kernel.name())),
            ("fuse_epilogue", Json::Bool(self.fuse_epilogue)),
            ("prepack", Json::Bool(self.prepack)),
            ("numerics", json::s(self.numerics.name())),
            ("predicted_seconds", json::num(self.predicted_seconds)),
            ("trace", Json::Arr(trace)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ExecutionPlan> {
        let format = j.get("format").and_then(Json::as_str).unwrap_or("");
        if format != PLAN_FORMAT {
            bail!("unsupported plan format {format:?} (want {PLAN_FORMAT})");
        }
        let get_u = |f: &str| {
            j.get(f)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("plan missing/invalid field {f:?}"))
        };
        let get_d = |f: &str| {
            j.get(f)
                .and_then(Json::as_str)
                .and_then(Dtype::parse)
                .ok_or_else(|| anyhow!("plan missing/invalid dtype field {f:?}"))
        };
        let kernel_text = j
            .get("kernel")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("plan missing kernel"))?;
        let kernel = KernelPolicy::parse(kernel_text)?;
        let mut plan_trace = Vec::new();
        if let Some(arr) = j.get("trace").and_then(Json::as_arr) {
            for t in arr {
                plan_trace.push(PassTrace {
                    pass: t.get("pass").and_then(Json::as_str).unwrap_or("").to_string(),
                    decision: t
                        .get("decision")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    reason: t.get("reason").and_then(Json::as_str).unwrap_or("").to_string(),
                });
            }
        }
        Ok(ExecutionPlan {
            m: get_u("m")?,
            n: get_u("n")?,
            k: get_u("k")?,
            dtype_in: get_d("dtype_in")?,
            dtype_acc: get_d("dtype_acc")?,
            epilogue: j
                .get("epilogue")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("plan missing epilogue"))?
                .to_string(),
            kernel,
            fuse_epilogue: j
                .get("fuse_epilogue")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("plan missing fuse_epilogue"))?,
            // Absent in pre-prepack plan files: default off (speed-only —
            // a missing flag can never change bits).
            prepack: j.get("prepack").and_then(Json::as_bool).unwrap_or(false),
            // Absent in pre-pass-6 plan files: the class is implied by
            // the kernel (same back-compat rule as `prepack`).  Present
            // but inconsistent with the kernel is an error — a plan
            // must not promise bit-exactness its kernel breaks.
            numerics: match j.get("numerics").and_then(Json::as_str) {
                None => NumericsClass::of(&kernel),
                Some(text) => {
                    let class = NumericsClass::parse(text)?;
                    if class != NumericsClass::of(&kernel) {
                        bail!(
                            "plan numerics class {:?} is inconsistent with kernel \
                             {:?} (which implies {:?})",
                            text,
                            kernel.name(),
                            NumericsClass::of(&kernel).name()
                        );
                    }
                    class
                }
            },
            predicted_seconds: j
                .get("predicted_seconds")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            trace: plan_trace,
        })
    }

    pub fn from_text(text: &str) -> Result<ExecutionPlan> {
        let j = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        ExecutionPlan::from_json(&j)
    }

    /// Human-readable trace rendering for the CLI.
    pub fn render_trace(&self) -> String {
        let mut out = String::new();
        for t in &self.trace {
            out.push_str(&format!("{:<18} {:<36} {}\n", t.pass, t.decision, t.reason));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// The pass pipeline
// ---------------------------------------------------------------------------

fn ceil_div(x: usize, d: usize) -> usize {
    if d == 0 {
        return 0;
    }
    x / d + usize::from(x % d != 0)
}

/// Modeled element traffic of one cache-blocked GEMM sweep: A is
/// repacked once per NC column block, B is packed once in total, and C
/// takes a read+write per KC reduction block.
fn traffic_elems(m: usize, n: usize, k: usize, b: &Blocking) -> u64 {
    let a = m as u64 * k as u64 * ceil_div(n, b.nc) as u64;
    let bt = k as u64 * n as u64;
    let c = 2 * m as u64 * n as u64 * ceil_div(k, b.kc) as u64;
    a + bt + c
}

/// Pass 1 — tile selection: rank the autotuner's cache-block candidates
/// (`autotune::cpu_blockings`) with the traffic model above, under the
/// environment's cache-residency constraints (A panel in half of L2, B
/// panel in half of L3 — the paper's 48 KiB shared-memory budget logic).
fn pass_tile_selection(
    key: &GemmKey,
    env: &PlanEnv,
    forced: Option<KernelPolicy>,
) -> (Blocking, PassTrace) {
    if let Some(policy) = forced {
        let blocking = match policy {
            KernelPolicy::Naive => Blocking::default(),
            KernelPolicy::Tiled(b)
            | KernelPolicy::Threaded(b, _)
            | KernelPolicy::Simd(b, _, _) => b,
        };
        return (
            blocking,
            trace(
                "tile-selection",
                format!("{}x{}x{}", blocking.mc, blocking.kc, blocking.nc),
                format!("forced by plan override {}", policy.name()),
            ),
        );
    }
    let candidates = crate::autotune::cpu_blockings();
    let feasible = |b: &Blocking| {
        b.mc * b.kc * 4 <= env.l2_bytes / 2 && b.kc * b.nc * 4 <= env.l3_bytes / 2
    };
    let n_feasible = candidates.iter().filter(|b| feasible(b)).count();
    // Rank by modeled traffic; break ties toward the smallest packed
    // panels (least cache pressure), then largest mc/kc/nc so selection
    // is a strict total order and therefore deterministic.
    let score = |b: &Blocking| {
        (
            traffic_elems(key.m, key.n, key.k, b),
            (b.mc * b.kc + b.kc * b.nc) as u64 * 4,
            std::cmp::Reverse(b.mc),
            std::cmp::Reverse(b.kc),
            std::cmp::Reverse(b.nc),
        )
    };
    let pool: Vec<Blocking> = if n_feasible > 0 {
        candidates.iter().copied().filter(feasible).collect()
    } else {
        candidates
    };
    let best = pool
        .iter()
        .copied()
        .min_by_key(score)
        .unwrap_or_else(Blocking::default);
    let t = trace(
        "tile-selection",
        format!("{}x{}x{}", best.mc, best.kc, best.nc),
        format!(
            "min modeled traffic {} elems over {} feasible of {} candidates",
            traffic_elems(key.m, key.n, key.k, &best),
            n_feasible,
            crate::autotune::cpu_blockings().len(),
        ),
    );
    (best, t)
}

/// Pass 2 — packing decision: below a footprint threshold (all three
/// operands within half of L2) the panel-packing copies are pure
/// overhead — the operands are already cache-resident — so the plan
/// lowers to the direct (unpacked, naive-loop) kernel instead.
fn pass_packing(key: &GemmKey, env: &PlanEnv, forced: Option<KernelPolicy>) -> (bool, PassTrace) {
    if let Some(policy) = forced {
        let packed = !matches!(policy, KernelPolicy::Naive);
        return (
            packed,
            trace(
                "packing",
                if packed { "packed panels" } else { "direct (unpacked)" }.to_string(),
                format!("forced by plan override {}", policy.name()),
            ),
        );
    }
    let footprint = 4 * (key.m * key.k + key.k * key.n + key.m * key.n);
    let threshold = env.l2_bytes / 2;
    let packed = footprint > threshold;
    let t = trace(
        "packing",
        if packed { "packed panels" } else { "direct (unpacked)" }.to_string(),
        format!(
            "operand footprint {footprint} B vs {threshold} B threshold (L2 {} B)",
            env.l2_bytes
        ),
    );
    (packed, t)
}

/// Pass 3 — thread partitioning: row-band count from the problem shape
/// and the pool size, replacing the engine's hard-coded auto heuristic.
/// A pool of executor workers (the server) gets single-thread plans —
/// intra-GEMM threading there would oversubscribe the host.
fn pass_threading(
    key: &GemmKey,
    env: &PlanEnv,
    forced: Option<KernelPolicy>,
    packed: bool,
) -> (usize, PassTrace) {
    if let Some(policy) = forced {
        let bands = match policy {
            KernelPolicy::Threaded(_, t) | KernelPolicy::Simd(_, t, _) => t,
            _ => 1,
        };
        return (
            bands,
            trace(
                "thread-partition",
                if bands == 0 { "auto bands".to_string() } else { format!("{bands} band(s)") },
                format!("forced by plan override {}", policy.name()),
            ),
        );
    }
    if !packed {
        return (
            1,
            trace(
                "thread-partition",
                "1 band".to_string(),
                "direct kernel: problem is below the fan-out threshold".to_string(),
            ),
        );
    }
    if env.pool_threads > 1 {
        return (
            1,
            trace(
                "thread-partition",
                "1 band".to_string(),
                format!(
                    "host shared by {} executor workers; intra-GEMM threading would \
                     oversubscribe",
                    env.pool_threads
                ),
            ),
        );
    }
    let hw = env.resolved_hw();
    let flops = 2.0 * key.m as f64 * key.n as f64 * key.k as f64;
    let by_work = (flops / kernel::MIN_FLOPS_PER_THREAD) as usize;
    let bands = hw.min(by_work.max(1)).min(ceil_div(key.m, MR)).max(1);
    let t = trace(
        "thread-partition",
        format!("{bands} band(s)"),
        format!(
            "min(hw {hw}, work {}, row panels {})",
            by_work.max(1),
            ceil_div(key.m, MR).max(1)
        ),
    );
    (bands, t)
}

/// Pass 4 — epilogue attachment: fuse bias+activation into the kernel's
/// per-band write-back (the paper's Table 1 fused column).  Bit-exact
/// rule: the epilogue is applied exactly once per element, after that
/// element's full k-reduction, so a fused plan is bit-identical to the
/// separate-pass form and sharding's epilogue-replay reduction is
/// unaffected.
fn pass_epilogue(key: &GemmKey) -> (bool, PassTrace) {
    let fuse = key.epilogue != "none";
    let t = trace(
        "epilogue",
        if fuse {
            format!("fuse {} into write-back", key.epilogue)
        } else {
            "no epilogue".to_string()
        },
        "applied once per element after the full k-reduction; bit-identical to a \
         separate pass, shard reductions replay it"
            .to_string(),
    );
    (fuse, t)
}

/// Pass 5 — prepack: when a B operand is *bound* (a constant weight
/// served to many requests), should its panels be materialized once at
/// bind time?  By the same traffic model as tile selection, the per-call
/// packing cost is one full copy of B (`k*n` elements) plus the request
/// payload that shipped it; the direct (naive) kernel streams B unpacked
/// and would never read panels, so prepacking follows the packing
/// decision exactly: panels iff the lowered kernel packs.
fn pass_prepack(key: &GemmKey, kernel: &KernelPolicy) -> (bool, PassTrace) {
    let packs = !matches!(kernel, KernelPolicy::Naive);
    let panel_bytes = 4 * key.k * key.n;
    let t = trace(
        "prepack",
        if packs { "prepack B panels at bind" } else { "no prepack" }.to_string(),
        if packs {
            format!(
                "lowered kernel packs B per call: binding saves the {panel_bytes} B \
                 panel copy (and the operand payload) on every request"
            )
        } else {
            format!(
                "direct kernel streams B unpacked; {panel_bytes} B of panels would \
                 be dead weight"
            )
        },
    );
    (packs, t)
}

/// Pass 6 — isa: lower the register tile to an explicit-SIMD nanokernel
/// (`runtime::nanokernel`) or keep the bit-exact scalar micro kernel.
/// The conservative default is scalar: SIMD changes bits (FMA
/// contraction), so it is opt-in (`--plan simd` / a forced `simd:<isa>`
/// policy), and the pass records the resulting [`NumericsClass`] as
/// part of its decision.  Runs *after* the kernel shape is known but
/// *before* the prepack pass in `compile` (prepack must see the final
/// kernel); in the recorded trace it appears last, as pass 6.
fn pass_isa(
    env: &PlanEnv,
    forced: Option<KernelPolicy>,
    simd_requested: bool,
    auto_kernel: KernelPolicy,
    blocking: Blocking,
    bands: usize,
) -> Result<(KernelPolicy, NumericsClass, PassTrace)> {
    if let Some(policy) = forced {
        let class = NumericsClass::of(&policy);
        let label = match policy {
            KernelPolicy::Simd(_, _, isa) => format!("simd:{}", isa.name()),
            _ => "scalar".to_string(),
        };
        return Ok((
            policy,
            class,
            trace(
                "isa",
                format!("{label} [{}]", class.name()),
                format!("forced by plan override {}", policy.name()),
            ),
        ));
    }
    if !simd_requested {
        return Ok((
            auto_kernel,
            NumericsClass::BitExact,
            trace(
                "isa",
                "scalar [bit_exact]".to_string(),
                "scalar micro kernel preserves the bit-exact contract; opt in to \
                 nanokernels with --plan simd"
                    .to_string(),
            ),
        ));
    }
    // SIMD requested: resolve the ISA per the environment's preference.
    let (resolved, how) = match env.isa {
        IsaPref::Scalar => (None, "IsaPref::Scalar".to_string()),
        IsaPref::Fixed(isa) => (Some(isa), format!("pinned to {}", isa.name())),
        IsaPref::Detect => {
            let det = nanokernel::detect()?;
            let env_forced = std::env::var(nanokernel::FORCE_ISA_ENV)
                .map(|v| !v.trim().is_empty())
                .unwrap_or(false);
            let how = match det {
                None => format!("{}=scalar forced the fallback", nanokernel::FORCE_ISA_ENV),
                Some(isa) if env_forced => {
                    format!("{}={} pinned it", nanokernel::FORCE_ISA_ENV, isa.name())
                }
                Some(isa) => {
                    format!("host probe (is_x86_feature_detected) picked {}", isa.name())
                }
            };
            (det, how)
        }
    };
    match resolved {
        Some(isa) => {
            // Lower even problems the scalar pipeline would run naive:
            // the nanokernel consumes packed panels regardless, and the
            // operator explicitly asked for SIMD.
            let kernel = KernelPolicy::Simd(blocking, bands, isa);
            Ok((
                kernel,
                NumericsClass::FmaRelaxed,
                trace(
                    "isa",
                    format!("simd:{} [fma_relaxed]", isa.name()),
                    format!(
                        "simd requested; {how}; FMA contraction breaks bit-exactness, \
                         verified by the condition-scaled tolerance instead"
                    ),
                ),
            ))
        }
        None => Ok((
            auto_kernel,
            NumericsClass::BitExact,
            trace(
                "isa",
                "scalar [bit_exact]".to_string(),
                format!("simd requested but the scalar fallback is forced ({how})"),
            ),
        )),
    }
}

/// Coarse host cost estimate used for predicted-vs-measured reporting;
/// deliberately simple (effective GFLOP/s per kernel class).  The SIMD
/// rate models the 4x16 FMA register tile at roughly 4x the scalar
/// tiled kernel's throughput per band.
fn predict_seconds(key: &GemmKey, kernel: &KernelPolicy) -> f64 {
    const TILED_FLOPS_PER_SEC: f64 = 4.0e9;
    const NAIVE_FLOPS_PER_SEC: f64 = 1.5e9;
    const SIMD_FLOPS_PER_SEC: f64 = 16.0e9;
    let flops = 2.0 * key.m as f64 * key.n as f64 * key.k as f64;
    match *kernel {
        KernelPolicy::Naive => flops / NAIVE_FLOPS_PER_SEC,
        KernelPolicy::Tiled(_) => flops / TILED_FLOPS_PER_SEC,
        KernelPolicy::Threaded(_, t) => flops / (TILED_FLOPS_PER_SEC * t.max(1) as f64),
        KernelPolicy::Simd(_, t, _) => flops / (SIMD_FLOPS_PER_SEC * t.max(1) as f64),
    }
}

/// Compile a [`GemmKey`] into an [`ExecutionPlan`] by running the pass
/// pipeline.  Deterministic for a fixed environment; errors only when a
/// forced override carries an invalid blocking.
pub fn compile(key: &GemmKey, env: &PlanEnv) -> Result<ExecutionPlan> {
    let (forced, simd_requested) = match env.force {
        PlanOverride::Auto => (None, false),
        PlanOverride::Simd => (None, true),
        PlanOverride::Force(p) => {
            p.validate()?;
            (Some(p), false)
        }
    };
    let mut plan_trace = Vec::with_capacity(6);
    let (blocking, t1) = pass_tile_selection(key, env, forced);
    plan_trace.push(t1);
    let (packed, t2) = pass_packing(key, env, forced);
    plan_trace.push(t2);
    let (bands, t3) = pass_threading(key, env, forced, packed);
    plan_trace.push(t3);
    let (fuse_epilogue, t4) = pass_epilogue(key);
    plan_trace.push(t4);
    let auto_kernel = match forced {
        Some(p) => p,
        None if !packed => KernelPolicy::Naive,
        None if bands > 1 => KernelPolicy::Threaded(blocking, bands),
        None => KernelPolicy::Tiled(blocking),
    };
    // Pass 6 runs before pass 5 records its decision: prepack is a pure
    // function of the *final* kernel (a SIMD lowering packs B even where
    // the scalar pipeline would have gone naive).  The trace keeps
    // pipeline order, with isa last.
    let (kernel, numerics, t6) =
        pass_isa(env, forced, simd_requested, auto_kernel, blocking, bands)?;
    let (prepack, t5) = pass_prepack(key, &kernel);
    plan_trace.push(t5);
    plan_trace.push(t6);
    Ok(ExecutionPlan {
        m: key.m,
        n: key.n,
        k: key.k,
        dtype_in: key.dtype_in,
        dtype_acc: key.dtype_acc,
        epilogue: key.epilogue.clone(),
        kernel,
        fuse_epilogue,
        prepack,
        numerics,
        predicted_seconds: predict_seconds(key, &kernel),
        trace: plan_trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_problem_compiles_to_direct_naive_plan() {
        let plan = compile(&GemmKey::plain(64, 64, 64), &PlanEnv::pinned()).unwrap();
        assert_eq!(plan.kernel, KernelPolicy::Naive);
        assert!(!plan.fuse_epilogue);
        assert!(!plan.prepack, "direct kernels never prepack");
        assert_eq!(plan.numerics, NumericsClass::BitExact);
        assert_eq!(plan.trace.len(), 6);
        assert!(plan.trace[1].decision.contains("direct"), "{:?}", plan.trace[1]);
        assert_eq!(plan.trace[4].pass, "prepack");
        assert_eq!(plan.trace[5].pass, "isa");
        assert!(plan.trace[5].decision.contains("scalar"), "{:?}", plan.trace[5]);
    }

    #[test]
    fn large_problem_compiles_to_threaded_tiled_plan() {
        let plan = compile(&GemmKey::plain(1024, 1024, 1024), &PlanEnv::pinned()).unwrap();
        assert!(plan.prepack, "packing kernels prepack bound weights");
        match plan.kernel {
            KernelPolicy::Threaded(b, t) => {
                assert_eq!(t, 4, "pinned env has 4 hw threads");
                assert!(b.mc * b.kc * 4 <= PlanEnv::pinned().l2_bytes / 2);
            }
            other => panic!("expected a threaded plan, got {other:?}"),
        }
    }

    #[test]
    fn pool_environment_disables_intra_gemm_threading() {
        let env = PlanEnv::for_pool(8);
        let plan = compile(&GemmKey::plain(1024, 1024, 1024), &env).unwrap();
        assert!(
            matches!(plan.kernel, KernelPolicy::Tiled(_)),
            "pooled executor must get a single-thread plan, got {:?}",
            plan.kernel
        );
    }

    #[test]
    fn epilogue_key_compiles_to_fused_plan() {
        let mut key = GemmKey::plain(512, 512, 512);
        key.epilogue = "bias_relu".into();
        let plan = compile(&key, &PlanEnv::pinned()).unwrap();
        assert!(plan.fuse_epilogue);
        assert!(plan.id().ends_with("+bias_relu"), "{}", plan.id());
        // ids must separate precision modes and epilogues at one shape
        let f16acc = GemmKey::with_dtypes(512, 512, 512, Dtype::F16, Dtype::F16);
        let f32acc = GemmKey::with_dtypes(512, 512, 512, Dtype::F16, Dtype::F32);
        let a = compile(&f16acc, &PlanEnv::pinned()).unwrap();
        let b = compile(&f32acc, &PlanEnv::pinned()).unwrap();
        assert_ne!(a.id(), b.id(), "dtype_acc must be part of the plan id");
        assert_ne!(plan.id(), b.id(), "epilogue must be part of the plan id");
    }

    #[test]
    fn override_forces_the_lowered_kernel_and_records_provenance() {
        let env = PlanEnv::pinned().with_force(PlanOverride::parse("naive").unwrap());
        let plan = compile(&GemmKey::plain(2048, 2048, 2048), &env).unwrap();
        assert_eq!(plan.kernel, KernelPolicy::Naive);
        assert!(plan.trace.iter().all(|t| !t.reason.is_empty()));
        assert!(plan.trace[0].reason.contains("forced"), "{:?}", plan.trace[0]);
        let forced = PlanOverride::parse("threaded:64,128,256,3").unwrap();
        let plan = compile(&GemmKey::plain(64, 64, 64), &PlanEnv::pinned().with_force(forced))
            .unwrap();
        assert_eq!(
            plan.kernel,
            KernelPolicy::Threaded(Blocking { mc: 64, kc: 128, nc: 256 }, 3)
        );
    }

    #[test]
    fn override_with_zero_blocking_is_a_compile_error() {
        assert!(PlanOverride::parse("tiled:0,128,256").is_err());
        assert!(PlanOverride::parse("simd:avx2:0,128,256").is_err());
        assert!(PlanOverride::parse("nonsense").is_err());
        assert_eq!(PlanOverride::parse("auto").unwrap(), PlanOverride::Auto);
        assert_eq!(PlanOverride::parse("simd").unwrap(), PlanOverride::Simd);
    }

    #[test]
    fn simd_override_lowers_to_a_nanokernel_with_fma_relaxed_class() {
        // pinned() fixes the ISA (no host probe): deterministic goldens.
        let env = PlanEnv::pinned().with_force(PlanOverride::Simd);
        let plan = compile(&GemmKey::plain(512, 512, 512), &env).unwrap();
        match plan.kernel {
            KernelPolicy::Simd(b, t, isa) => {
                assert_eq!(isa, Isa::Avx2Fma);
                assert_eq!(t, 4, "pass 3's band count carries into the simd kernel");
                assert!(b.validate().is_ok());
            }
            other => panic!("expected a simd kernel, got {other:?}"),
        }
        assert_eq!(plan.numerics, NumericsClass::FmaRelaxed);
        assert_eq!(plan.isa_label(), "simd:avx2");
        assert!(plan.prepack, "simd kernels pack B, so bound weights prepack");
        assert_eq!(plan.trace.len(), 6);
        assert!(plan.trace[5].decision.contains("fma_relaxed"), "{:?}", plan.trace[5]);

        // Even a cache-resident problem lowers to simd when asked: the
        // operator's explicit request wins over the packing heuristic.
        let small = compile(&GemmKey::plain(24, 24, 24), &env).unwrap();
        assert!(matches!(small.kernel, KernelPolicy::Simd(..)), "{:?}", small.kernel);
        assert!(small.prepack, "prepack follows the final (simd) kernel");
    }

    #[test]
    fn scalar_isa_pref_keeps_the_bit_exact_pipeline_result() {
        let env = PlanEnv::pinned()
            .with_force(PlanOverride::Simd)
            .with_isa(IsaPref::Scalar);
        let plan = compile(&GemmKey::plain(512, 512, 512), &env).unwrap();
        assert_eq!(plan.numerics, NumericsClass::BitExact);
        assert_eq!(plan.isa_label(), "scalar");
        assert!(
            matches!(plan.kernel, KernelPolicy::Threaded(..)),
            "falls back to the auto pipeline's kernel, got {:?}",
            plan.kernel
        );
        assert!(plan.trace[5].reason.contains("scalar fallback"), "{:?}", plan.trace[5]);
        // And the same plan as plain auto — forcing scalar under a simd
        // request is exactly "ignore the simd request".
        let auto = compile(&GemmKey::plain(512, 512, 512), &PlanEnv::pinned()).unwrap();
        assert_eq!(plan.kernel, auto.kernel);
    }

    #[test]
    fn forced_simd_policy_compiles_with_its_own_blocking_and_class() {
        let forced = PlanOverride::parse("simd:portable:64,128,256,2").unwrap();
        let plan = compile(
            &GemmKey::plain(256, 256, 256),
            &PlanEnv::pinned().with_force(forced),
        )
        .unwrap();
        assert_eq!(
            plan.kernel,
            KernelPolicy::Simd(Blocking { mc: 64, kc: 128, nc: 256 }, 2, Isa::Portable)
        );
        assert_eq!(plan.numerics, NumericsClass::FmaRelaxed);
        assert_eq!(plan.isa_label(), "simd:portable");
        assert!(plan.trace[5].reason.contains("forced"), "{:?}", plan.trace[5]);
    }

    #[test]
    fn numerics_class_follows_the_kernel_and_round_trips() {
        assert_eq!(NumericsClass::parse("bit_exact").unwrap(), NumericsClass::BitExact);
        assert_eq!(NumericsClass::parse("fma_relaxed").unwrap(), NumericsClass::FmaRelaxed);
        assert!(NumericsClass::parse("loose").is_err());
        assert_eq!(NumericsClass::of(&KernelPolicy::Naive), NumericsClass::BitExact);
        assert_eq!(
            NumericsClass::of(&KernelPolicy::Simd(Blocking::default(), 0, Isa::Neon)),
            NumericsClass::FmaRelaxed
        );

        let env = PlanEnv::pinned().with_force(PlanOverride::Simd);
        let plan = compile(&GemmKey::plain(512, 512, 512), &env).unwrap();
        let text = plan.to_json().to_string();
        assert!(text.contains("\"numerics\""), "{text}");
        let back = ExecutionPlan::from_text(&text).unwrap();
        assert_eq!(back.numerics, NumericsClass::FmaRelaxed);
        assert_eq!(plan, back);

        // A legacy plan file without the field gets the kernel-implied
        // class; an inconsistent pair is rejected.
        let legacy = text.replace("\"numerics\": \"fma_relaxed\", ", "");
        if legacy != text {
            let back = ExecutionPlan::from_text(&legacy).unwrap();
            assert_eq!(back.numerics, NumericsClass::FmaRelaxed);
        }
        let lying = text.replace("fma_relaxed", "bit_exact");
        assert!(
            ExecutionPlan::from_text(&lying).is_err(),
            "a simd kernel must not claim bit_exact"
        );
    }

    #[test]
    fn json_round_trip_preserves_the_plan_exactly() {
        for key in [
            GemmKey::plain(64, 64, 64),
            GemmKey::plain(1024, 1024, 1024),
            GemmKey {
                m: 300,
                n: 200,
                k: 100,
                dtype_in: Dtype::F32,
                dtype_acc: Dtype::F16,
                epilogue: "bias_relu".into(),
            },
        ] {
            let plan = compile(&key, &PlanEnv::pinned()).unwrap();
            let text = plan.to_json().to_string();
            let back = ExecutionPlan::from_text(&text).unwrap();
            assert_eq!(plan, back, "round trip drifted for {key:?}");
        }
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(ExecutionPlan::from_text("{}").is_err());
        assert!(ExecutionPlan::from_text("not json").is_err());
        let plan = compile(&GemmKey::plain(64, 64, 64), &PlanEnv::pinned()).unwrap();
        let bad = plan.to_json().to_string().replace("plan-v1", "plan-v9");
        assert!(ExecutionPlan::from_text(&bad).is_err());
        let bad_kernel = plan.to_json().to_string().replace("naive", "warp9");
        assert!(ExecutionPlan::from_text(&bad_kernel).is_err());
    }

    #[test]
    fn zero_dims_compile_without_panicking() {
        let plan = compile(&GemmKey::plain(0, 0, 0), &PlanEnv::pinned()).unwrap();
        // Degenerate problems lower to the direct kernel, one band.
        assert_eq!(plan.kernel, KernelPolicy::Naive);
    }

    #[test]
    fn manual_plan_validates_blocking() {
        let key = GemmKey::plain(32, 32, 32);
        assert!(ExecutionPlan::manual(
            &key,
            KernelPolicy::Tiled(Blocking { mc: 0, kc: 8, nc: 8 }),
            false
        )
        .is_err());
        let plan = ExecutionPlan::manual(&key, KernelPolicy::Naive, false).unwrap();
        assert!(plan.matches_gemm(32, 32, 32, Dtype::F16, Dtype::F32, "none"));
        assert!(!plan.matches_gemm(32, 32, 33, Dtype::F16, Dtype::F32, "none"));
    }

    #[test]
    fn prepack_b_follows_the_pass_decision_and_matches_per_call_packing() {
        use crate::util::prng::Rng;
        // Direct plan: no panels.
        let naive = compile(&GemmKey::plain(16, 16, 16), &PlanEnv::pinned()).unwrap();
        assert!(naive.prepack_b(&vec![0.0; 16 * 16]).is_none());
        // Packed plan: panels exist and execute bit-identically to raw B.
        let key = GemmKey::with_dtypes(40, 24, 32, Dtype::F32, Dtype::F32);
        let env = PlanEnv::pinned()
            .with_force(PlanOverride::parse("tiled:8,4,16").unwrap());
        let plan = compile(&key, &env).unwrap();
        assert!(plan.prepack);
        let mut rng = Rng::new(0x9E);
        let a = rng.normal_matrix(40, 32);
        let b = rng.normal_matrix(32, 24);
        let pre = plan.prepack_b(&b).expect("packed plan prepacks");
        let mut want = vec![0.0f32; 40 * 24];
        plan.matmul(&mut want, &a, &b);
        let mut got = vec![0.0f32; 40 * 24];
        plan.matmul_b(&mut got, &a, BOperand::Prepacked(&pre));
        assert!(
            want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
            "prepacked plan execution drifted"
        );
    }

    #[test]
    fn plan_matmul_matches_raw_kernel() {
        use crate::util::prng::Rng;
        let key = GemmKey::with_dtypes(20, 12, 16, Dtype::F32, Dtype::F32);
        let plan = compile(&key, &PlanEnv::pinned()).unwrap();
        let mut rng = Rng::new(5);
        let a = rng.normal_matrix(20, 16);
        let b = rng.normal_matrix(16, 12);
        let mut want = vec![0.0f32; 20 * 12];
        kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, 20, 12, 16);
        let mut got = vec![0.0f32; 20 * 12];
        plan.matmul(&mut got, &a, &b);
        assert_eq!(want, got);
    }
}
