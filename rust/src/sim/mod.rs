//! Analytic GPU performance model — the substitute testbed for the paper's
//! RTX 3090 (see DESIGN.md §1 "substitutions").  `device` holds the
//! hardware constants, `model` the per-kernel cost model, `library` the
//! simulated cuBLAS comparator.

pub mod device;
pub mod library;
pub mod model;

pub use device::DeviceModel;
pub use library::{library_tile_choice, simulate_library, LIBRARY_COMPUTE_EFF};
pub use model::{occupancy, simulate, simulate_with_eff, Occupancy, SimResult,
                GENERATED_COMPUTE_EFF};
