//! The kernel cost model: Schedule + DeviceModel -> cycles/TFLOPs.
//!
//! A first-order analytic model of one GEMM kernel launch, built from the
//! same quantities the paper's §3 reasons about.  Every optimization toggle
//! in the schedule maps to a term:
//!
//! * no tiling          -> CUDA-core compute, zero reuse (every FMA pays
//!   global traffic), C read-modify-written per k step;
//! * tiling w/o smem    -> per-warp redundant global reads of the A/B tiles
//!   (L1-cache discounted), still no staging;
//! * shared memory      -> A/B tiles hit global once per k-iteration;
//! * wmma               -> tensor-core instead of CUDA-core throughput;
//! * unroll/hoist       -> C traffic once per block instead of per k-iter;
//! * latency hiding     -> copy and compute overlap (max instead of sum),
//!   global latency amortized across pipeline stages;
//! * padding            -> removes the shared-memory bank-conflict factor;
//! * vectorize          -> full-width global transactions.
//!
//! Occupancy follows the CUDA occupancy rules (blocks limited by shared
//! memory, registers, threads, block slots), which is what makes small
//! problem sizes favour small tiles exactly as §4.1 observes.

use crate::schedule::Schedule;
use super::device::DeviceModel;

/// Shared-memory bank-conflict multiplier for unpadded f16 tiles.  A
/// 16-byte-aligned row layout with a power-of-two leading dimension lands
/// consecutive fragment rows on the same banks; 4x is the measured ballpark
/// for WMMA-shaped accesses (Bhaskaracharya et al. report 2-8x swings).
const BANK_CONFLICT_FACTOR: f64 = 4.0;

/// L1 cache discount for redundant per-warp global reads (no-smem variant).
const L1_REUSE_DISCOUNT: f64 = 0.5;

/// Achieved fraction of peak global bandwidth for full-width (128-bit)
/// vectorized copies vs narrow scalar accesses.
const VEC_BW_EFF: f64 = 0.92;
const SCALAR_BW_EFF: f64 = 0.38;

/// Achievable fraction of the CUDA-core FMA peak for scalar (non-WMMA)
/// matmul inner loops: loads, address arithmetic, and loop control compete
/// with the FMAs for issue slots.  Tensor-core HMMA ops amortize all of
/// that over a 16x16x16 fragment, which is (most of) why the WMMA rewrite
/// is one of Figure 3's biggest jumps even though GeForce Ampere's
/// f32-accumulate TC rate numerically equals the CUDA-core f32 peak.
const CUDA_CORE_EFF: f64 = 0.40;

/// Tensor-core pipe efficiency of compiler-scheduled WMMA code vs perfectly
/// scheduled SASS.  The generated-code column of Table 1 ("competitive in
/// most cases"); the library model uses a higher figure.
pub const GENERATED_COMPUTE_EFF: f64 = 0.95;

#[derive(Debug, Clone)]
pub struct Occupancy {
    pub blocks_resident_per_sm: usize,
    pub limited_by: &'static str,
    pub active_sms: usize,
    pub waves: usize,
    /// Fraction of warp-scheduler slots kept busy.
    pub scheduler_util: f64,
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub name: String,
    pub seconds: f64,
    pub tflops: f64,
    /// Fraction of the device tensor-core peak for the accumulate dtype.
    pub frac_of_peak: f64,
    pub occupancy: Occupancy,
    /// Per-k-iteration cycle breakdown of one block (steady state).
    pub compute_cycles_per_iter: f64,
    pub memory_cycles_per_iter: f64,
    pub cycles_per_block: f64,
    pub bound: &'static str, // "compute" | "memory" | "latency" | "occupancy"
}

/// Saturating warp-ILP curve: fraction of the tensor pipe kept busy with
/// `w` warps resident per scheduler.  One warp already streams independent
/// MMAs from its unrolled accumulator tile (the §3.4 hoisting), so the
/// curve starts high and saturates at three warps/scheduler — the paper's
/// §2.2 "more warps help hide latency" effect, calibrated so an 8-warp
/// 128x128 block at low residency lands ~15% below peak (matching the
/// small-size gaps of Figure 2).
fn warp_ilp_util(warps_per_scheduler: f64) -> f64 {
    (0.55 + 0.15 * warps_per_scheduler).min(1.0)
}

/// Compute occupancy for a schedule on a device.
pub fn occupancy(s: &Schedule, d: &DeviceModel) -> Occupancy {
    let threads = s.threads_per_block.max(32);
    let mut limits: Vec<(usize, &'static str)> = vec![
        (d.max_blocks_per_sm, "block-slots"),
        (d.max_threads_per_sm / threads, "threads"),
    ];
    if s.shared_mem && s.smem_bytes > 0 {
        limits.push((d.smem_per_sm / s.smem_bytes, "shared-memory"));
    }
    let regs_per_block = s.regs_per_thread().min(d.max_regs_per_thread) * threads;
    limits.push((d.regs_per_sm / regs_per_block.max(1), "registers"));

    let (resident, limited_by) = limits
        .into_iter()
        .min_by_key(|(v, _)| *v)
        .unwrap();
    let resident = resident.max(1);

    let blocks = s.blocks();
    let active_sms = blocks.min(d.sms);
    // A block slot only helps if there is a block to fill it: small grids
    // cannot reach the resource-limited residency.
    let resident_eff = resident.min(blocks.div_ceil(d.sms)).max(1);
    let waves = blocks.div_ceil(d.sms * resident_eff).max(1);
    // Warp-level parallelism available to each SM's schedulers; the pipe
    // saturates around WARPS_PER_SCHED_FOR_PEAK resident warps/scheduler.
    let warps_active =
        (s.warps_total_per_block() * resident_eff).min(d.max_threads_per_sm / 32);
    let scheduler_util =
        warp_ilp_util(warps_active as f64 / d.warp_schedulers_per_sm as f64);
    Occupancy {
        blocks_resident_per_sm: resident_eff,
        limited_by,
        active_sms,
        waves,
        scheduler_util,
    }
}

/// Simulate one kernel launch; `compute_eff` is the tensor-pipe efficiency
/// of the code generator (use [`GENERATED_COMPUTE_EFF`] for our pipeline).
pub fn simulate_with_eff(s: &Schedule, d: &DeviceModel, compute_eff: f64) -> SimResult {
    if !s.tiling {
        return simulate_naive(s, d);
    }

    let occ = occupancy(s, d);
    let (tbm, tbn, tbk) = s.tile_tb;
    let (wm, wn, _) = s.tile_warp;
    let in_b = s.dtype_in.bytes() as f64;
    let acc_b = s.dtype_acc.bytes() as f64;
    let k_iters = (s.k / tbk) as f64;

    // ---- compute path (cycles per k-iteration of one block) -------------
    let flops_per_iter = 2.0 * tbm as f64 * tbn as f64 * tbk as f64;
    let pipe = if s.wmma {
        d.tc_flops_per_cycle_mode(s.dtype_in, s.dtype_acc)
    } else {
        d.cuda_flops_per_cycle * CUDA_CORE_EFF
    };
    let compute_raw = flops_per_iter / (pipe * occ.scheduler_util.max(0.1));
    let mut compute_cycles = compute_raw / compute_eff;

    // Shared-memory read pressure feeding the MXU/TC pipes: after CSE each
    // warp still re-reads its A slice per jjj column and B slice per iii
    // row.  Bank conflicts inflate this; padding removes them.
    if s.shared_mem {
        let a_reads = (tbm * tbk) as f64 * (tbn as f64 / wn as f64);
        let b_reads = (tbk * tbn) as f64 * (tbm as f64 / wm as f64);
        let conflict = if s.padding { 1.0 } else { BANK_CONFLICT_FACTOR };
        let smem_read_cycles =
            (a_reads + b_reads) * in_b * conflict / d.smem_bytes_per_cycle;
        compute_cycles = compute_cycles.max(smem_read_cycles);
    }

    // ---- memory path (global traffic cycles per k-iteration) ------------
    let tile_bytes = ((tbm * tbk) + (tbk * tbn)) as f64 * in_b;
    let global_bytes_per_iter = if s.shared_mem {
        tile_bytes
    } else {
        // Every warp re-reads the tiles it needs from global (L1-discounted).
        let warp_factor_a = (tbn / wn) as f64;
        let warp_factor_b = (tbm / wm) as f64;
        ((tbm * tbk) as f64 * warp_factor_a + (tbk * tbn) as f64 * warp_factor_b)
            * in_b
            * L1_REUSE_DISCOUNT
    };
    let bw_eff = if s.vectorize { VEC_BW_EFF } else { SCALAR_BW_EFF };
    let bw_per_sm = d.hbm_bytes_per_cycle_per_sm(occ.active_sms);
    // Problems whose whole working set is L2-resident see much higher
    // effective bandwidth (GA102's L2 sustains ~2.5x DRAM).
    let working_set = ((s.m * s.k + s.k * s.n) as f64 * in_b
        + (s.m * s.n) as f64 * acc_b) as usize;
    let l2_factor = if working_set <= 2 * d.l2_bytes { 0.4 } else { 1.0 };
    let mut memory_cycles =
        global_bytes_per_iter * l2_factor / (bw_per_sm * bw_eff);

    if s.shared_mem {
        let conflict = if s.padding { 1.0 } else { BANK_CONFLICT_FACTOR };
        memory_cycles += tile_bytes * conflict / d.smem_bytes_per_cycle;
    }

    // C traffic: once per block when hoisted, every k-iteration otherwise.
    let c_bytes = (tbm * tbn) as f64 * acc_b * 2.0; // read + write
    let c_cycles = c_bytes * l2_factor / (bw_per_sm * bw_eff);
    let mut c_per_iter = 0.0;
    let mut c_per_block = 0.0;
    if s.unroll_hoist {
        c_per_block = c_cycles;
    } else {
        c_per_iter = c_cycles;
    }
    memory_cycles += c_per_iter;

    // ---- latency structure ----------------------------------------------
    // Stall cycles (barriers, exposed load latency) are filled by other
    // resident blocks when occupancy allows.
    let resident = occ.blocks_resident_per_sm as f64;
    let latency_amort =
        d.global_latency_cycles / (s.pipeline_stages as f64) / resident;
    let barrier =
        s.barriers_per_iteration as f64 * d.barrier_cycles / resident;
    let (iter_cycles, bound) = if s.latency_hiding {
        let c = compute_cycles.max(memory_cycles) + barrier + latency_amort;
        let bound = if compute_cycles >= memory_cycles {
            "compute"
        } else {
            "memory"
        };
        (c, bound)
    } else {
        // Serial: wait on the copy, then compute.
        let lat = d.global_latency_cycles / resident;
        (compute_cycles + memory_cycles + barrier + lat, "latency")
    };

    // ---- assemble ---------------------------------------------------------
    // Sequential-equivalent SM time: the busiest SM runs
    // ceil(blocks/sms) blocks.  With multiple resident blocks the tail
    // wave overlaps earlier ones, smoothing the quantization toward the
    // average — the occupancy benefit §4.1 attributes to small tiles on
    // small problems.
    let prologue = d.global_latency_cycles + memory_cycles;
    let cycles_per_block = k_iters * iter_cycles + prologue + c_per_block;
    let avg_blocks = (s.blocks() as f64 / d.sms as f64).max(1.0);
    let ceil_blocks = s.blocks().div_ceil(d.sms) as f64;
    let per_sm_blocks = if occ.blocks_resident_per_sm > 1 {
        (avg_blocks + ceil_blocks) / 2.0
    } else {
        ceil_blocks
    };
    let total_cycles = per_sm_blocks * cycles_per_block;
    let mut seconds = total_cycles / d.clock_hz;

    // Hard ceilings: device-wide bandwidth and compute roofs.
    let total_global_bytes =
        s.blocks() as f64 * (k_iters * global_bytes_per_iter + c_bytes);
    seconds = seconds.max(total_global_bytes / d.hbm_bytes_per_sec);
    let peak = if s.wmma {
        d.peak_tc_flops(s.dtype_acc)
    } else {
        d.cuda_flops_per_cycle * d.sms as f64 * d.clock_hz
    };
    seconds = seconds.max(s.flops() / peak);

    let tflops = s.flops() / seconds / 1e12;
    SimResult {
        name: s.name.clone(),
        seconds,
        tflops,
        frac_of_peak: s.flops() / seconds / d.peak_tc_flops(s.dtype_acc),
        occupancy: occ,
        compute_cycles_per_iter: compute_cycles,
        memory_cycles_per_iter: memory_cycles,
        cycles_per_block,
        bound,
    }
}

pub fn simulate(s: &Schedule, d: &DeviceModel) -> SimResult {
    simulate_with_eff(s, d, GENERATED_COMPUTE_EFF)
}

/// The untiled kernel: one thread per output element, CUDA cores, no reuse.
fn simulate_naive(s: &Schedule, d: &DeviceModel) -> SimResult {
    let in_b = s.dtype_in.bytes() as f64;
    let acc_b = s.dtype_acc.bytes() as f64;
    let flops = s.flops();
    // Every FMA loads one A and one B element from global (caches help a
    // little; grant the same L1 discount as the tiled-no-smem variant) and
    // C is read-modify-written per k step without hoisting.
    let ab_bytes = (s.m * s.n * s.k) as f64 * 2.0 * in_b * L1_REUSE_DISCOUNT;
    let c_bytes = (s.m * s.n * s.k) as f64 * 2.0 * acc_b * L1_REUSE_DISCOUNT;
    let mem_seconds = (ab_bytes + c_bytes) / (d.hbm_bytes_per_sec * SCALAR_BW_EFF);
    let compute_seconds =
        flops / (d.cuda_flops_per_cycle * d.sms as f64 * d.clock_hz);
    let seconds = mem_seconds.max(compute_seconds);
    let tflops = flops / seconds / 1e12;
    SimResult {
        name: s.name.clone(),
        seconds,
        tflops,
        frac_of_peak: flops / seconds / d.peak_tc_flops(s.dtype_acc),
        occupancy: Occupancy {
            blocks_resident_per_sm: 1,
            limited_by: "untiled",
            active_sms: d.sms,
            waves: 1,
            scheduler_util: 1.0,
        },
        compute_cycles_per_iter: 0.0,
        memory_cycles_per_iter: 0.0,
        cycles_per_block: 0.0,
        bound: if mem_seconds > compute_seconds {
            "memory"
        } else {
            "compute"
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{Dtype, Schedule};

    fn sched(m: usize, tb: (usize, usize, usize), warp: (usize, usize, usize)) -> Schedule {
        Schedule::optimized(m, m, m, Dtype::F32, tb, warp).unwrap()
    }

    fn d() -> DeviceModel {
        DeviceModel::rtx3090()
    }

    #[test]
    fn large_mixed_precision_near_paper_range() {
        // paper: ~95% of the 35.6 TFLOPs device peak at 8192
        let r = simulate(&sched(8192, (128, 128, 64), (64, 32, 32)), &d());
        assert!(r.tflops > 28.0 && r.tflops <= 35.6, "{}", r.tflops);
    }

    #[test]
    fn f16_accumulate_roughly_doubles() {
        let s32 = sched(8192, (128, 128, 64), (64, 32, 32));
        let mut s16 = s32.clone();
        s16.dtype_acc = Dtype::F16;
        let r32 = simulate(&s32, &d());
        let r16 = simulate(&s16, &d());
        let ratio = r16.tflops / r32.tflops;
        assert!(ratio > 1.5 && ratio < 2.1, "ratio {ratio}");
    }

    #[test]
    fn occupancy_limits_small_problems() {
        // 1024 with 128x128 tiles -> 64 blocks < 82 SMs: underutilized
        let big_tile = simulate(&sched(1024, (128, 128, 64), (64, 32, 32)), &d());
        let small_tile = simulate(&sched(1024, (64, 64, 64), (32, 32, 32)), &d());
        assert!(
            small_tile.tflops > big_tile.tflops,
            "small tiles should win at 1024: {} vs {}",
            small_tile.tflops,
            big_tile.tflops
        );
    }

    #[test]
    fn large_problems_prefer_large_tiles() {
        let big_tile = simulate(&sched(8192, (128, 128, 64), (64, 32, 32)), &d());
        let small_tile = simulate(&sched(8192, (32, 32, 32), (16, 16, 16)), &d());
        assert!(
            big_tile.tflops > small_tile.tflops,
            "large tiles should win at 8192: {} vs {}",
            big_tile.tflops,
            small_tile.tflops
        );
    }

    #[test]
    fn monotone_in_disabled_optimizations() {
        // cumulative levels must not get slower as optimizations are added
        let base = Schedule::optimized(2048, 2048, 2048, Dtype::F32,
                                       (128, 128, 64), (64, 32, 32)).unwrap();
        let mut prev = 0.0;
        for level in 1..=7u8 {
            let mut s = base.clone();
            s.opt_level = level;
            s.shared_mem = level >= 2;
            s.wmma = level >= 3;
            s.unroll_hoist = level >= 4;
            s.latency_hiding = level >= 5;
            s.padding = level >= 6;
            s.vectorize = level >= 7;
            if !s.latency_hiding {
                s.pipeline_stages = 1;
            }
            let r = simulate(&s, &d());
            assert!(
                r.tflops >= prev * 0.999,
                "level {level} regressed: {} < {prev}",
                r.tflops
            );
            prev = r.tflops;
        }
    }

    #[test]
    fn naive_is_terrible() {
        let mut s = sched(2048, (128, 128, 64), (64, 32, 32));
        s.tiling = false;
        let r = simulate(&s, &d());
        assert!(r.tflops < 1.0, "naive should be <1 TFLOP, got {}", r.tflops);
    }

    #[test]
    fn never_exceeds_peak() {
        for &m in &[1024usize, 4096, 16384] {
            let r = simulate(&sched(m, (128, 128, 64), (64, 32, 32)), &d());
            assert!(r.frac_of_peak <= 1.0 + 1e-9, "{}", r.frac_of_peak);
        }
    }

    #[test]
    fn occupancy_respects_smem_limit() {
        let s = sched(8192, (128, 128, 64), (64, 32, 32));
        let o = occupancy(&s, &d());
        assert!(o.blocks_resident_per_sm * s.smem_bytes <= d().smem_per_sm);
    }
}
