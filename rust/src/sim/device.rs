//! Analytic device models, calibrated from vendor whitepapers.
//!
//! The paper's testbed is an Ampere GeForce RTX 3090 (GA102) locked to its
//! 1695 MHz boost clock.  The constants below come from the GA102
//! whitepaper and the CUDA occupancy tables; the A100 preset is included
//! for the ablation benches that ask "would the conclusions change on a
//! data-center part?".

use crate::schedule::Dtype;

#[derive(Debug, Clone, PartialEq)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Locked SM clock in Hz (the paper pins 1695 MHz).
    pub clock_hz: f64,
    /// Dense tensor-core flops per cycle per SM with f16 accumulate.
    /// (RTX 3090: 71 TFLOPS f16/f16 dense = 512 flop/cycle/SM.)
    pub tc_flops_per_cycle_f16acc: f64,
    /// Dense tensor-core flops per cycle per SM with f32 accumulate.
    /// (GeForce Ampere halves the f32-accumulate rate: 35.6 TFLOPS.)
    pub tc_flops_per_cycle_f32acc: f64,
    /// Dense tensor-core flops per cycle per SM in TF32 mode (f32 inputs
    /// converted internally; RTX 3090: 17.8 TFLOPS dense).
    pub tc_flops_per_cycle_tf32: f64,
    /// CUDA-core f32 FMA flops per cycle per SM (128 cores x 2).
    pub cuda_flops_per_cycle: f64,
    /// Device global-memory bandwidth, bytes/s (GDDR6X: 936 GB/s).
    pub hbm_bytes_per_sec: f64,
    /// Global-memory load latency in cycles.
    pub global_latency_cycles: f64,
    /// Shared-memory bandwidth per SM, bytes/cycle (32 banks x 4 B).
    pub smem_bytes_per_cycle: f64,
    /// Shared memory available per SM for occupancy (GA102: 100 KiB).
    pub smem_per_sm: usize,
    /// Static shared-memory limit per block (the paper restricts to 48 KiB).
    pub smem_static_limit: usize,
    /// Register file per SM (32-bit registers).
    pub regs_per_sm: usize,
    /// Max registers per thread (paper sets 255).
    pub max_regs_per_thread: usize,
    pub max_threads_per_sm: usize,
    pub max_blocks_per_sm: usize,
    pub warp_schedulers_per_sm: usize,
    /// Cycles for a block-wide barrier.
    pub barrier_cycles: f64,
    /// L2 cache capacity in bytes (GA102: 6 MiB).
    pub l2_bytes: usize,
}

impl DeviceModel {
    pub fn rtx3090() -> DeviceModel {
        let clock = 1.695e9;
        let sms = 82.0;
        DeviceModel {
            name: "rtx3090",
            sms: 82,
            clock_hz: clock,
            // 71e12 / (82 * 1.695e9) = 511 -> 512 flops/cycle/SM
            tc_flops_per_cycle_f16acc: 71.0e12 / (sms * clock),
            // 35.6e12 -> 256 flops/cycle/SM
            tc_flops_per_cycle_f32acc: 35.6e12 / (sms * clock),
            tc_flops_per_cycle_tf32: 17.8e12 / (sms * clock),
            // 10496 cores * 2 flops / 82 SM = 256 flops/cycle/SM
            cuda_flops_per_cycle: 35.6e12 / (sms * clock),
            hbm_bytes_per_sec: 936.0e9,
            global_latency_cycles: 470.0,
            smem_bytes_per_cycle: 128.0,
            smem_per_sm: 100 * 1024,
            smem_static_limit: 48 * 1024,
            regs_per_sm: 65536,
            max_regs_per_thread: 255,
            max_threads_per_sm: 1536,
            max_blocks_per_sm: 16,
            warp_schedulers_per_sm: 4,
            barrier_cycles: 25.0,
            l2_bytes: 6 * 1024 * 1024,
        }
    }

    pub fn a100() -> DeviceModel {
        let clock = 1.41e9;
        let sms = 108.0;
        DeviceModel {
            name: "a100",
            sms: 108,
            clock_hz: clock,
            // A100 does NOT halve f32 accumulate: 312 TFLOPS dense both ways.
            tc_flops_per_cycle_f16acc: 312.0e12 / (sms * clock),
            tc_flops_per_cycle_f32acc: 312.0e12 / (sms * clock),
            tc_flops_per_cycle_tf32: 156.0e12 / (sms * clock),
            cuda_flops_per_cycle: 19.5e12 / (sms * clock),
            hbm_bytes_per_sec: 1555.0e9,
            global_latency_cycles: 450.0,
            smem_bytes_per_cycle: 128.0,
            smem_per_sm: 164 * 1024,
            smem_static_limit: 48 * 1024,
            regs_per_sm: 65536,
            max_regs_per_thread: 255,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            warp_schedulers_per_sm: 4,
            barrier_cycles: 25.0,
            l2_bytes: 40 * 1024 * 1024,
        }
    }

    pub fn by_name(name: &str) -> Option<DeviceModel> {
        match name {
            "rtx3090" => Some(DeviceModel::rtx3090()),
            "a100" => Some(DeviceModel::a100()),
            _ => None,
        }
    }

    pub fn tc_flops_per_cycle(&self, acc: Dtype) -> f64 {
        match acc {
            Dtype::F16 | Dtype::Bf16 => self.tc_flops_per_cycle_f16acc,
            Dtype::F32 => self.tc_flops_per_cycle_f32acc,
        }
    }

    /// Tensor-core rate keyed on the *input* format (§2.3 of the paper):
    /// f16 and bf16 inputs run at the same rate; f32 inputs go through the
    /// TF32 path, which is slower than both.
    pub fn tc_flops_per_cycle_mode(&self, dtype_in: Dtype, acc: Dtype) -> f64 {
        match dtype_in {
            Dtype::F16 | Dtype::Bf16 => self.tc_flops_per_cycle(acc),
            Dtype::F32 => self.tc_flops_per_cycle_tf32,
        }
    }

    /// Device peak for a given accumulate dtype on tensor cores, flops/s.
    pub fn peak_tc_flops(&self, acc: Dtype) -> f64 {
        self.tc_flops_per_cycle(acc) * self.sms as f64 * self.clock_hz
    }

    /// Global bandwidth expressed per SM per cycle.
    pub fn hbm_bytes_per_cycle_per_sm(&self, active_sms: usize) -> f64 {
        self.hbm_bytes_per_sec / (active_sms.max(1) as f64) / self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtx3090_peaks_match_whitepaper() {
        let d = DeviceModel::rtx3090();
        let f16 = d.peak_tc_flops(Dtype::F16) / 1e12;
        let f32 = d.peak_tc_flops(Dtype::F32) / 1e12;
        assert!((f16 - 71.0).abs() < 0.5, "{f16}");
        assert!((f32 - 35.6).abs() < 0.5, "{f32}");
    }

    #[test]
    fn f16_acc_is_double_rate_on_geforce() {
        let d = DeviceModel::rtx3090();
        let ratio = d.tc_flops_per_cycle(Dtype::F16) / d.tc_flops_per_cycle(Dtype::F32);
        assert!((ratio - 2.0).abs() < 0.02, "{ratio}");
    }

    #[test]
    fn precision_mode_ordering_matches_paper_s2_3() {
        // §2.3: bf16 and f16 are the same speed, both faster than TF32
        let d = DeviceModel::rtx3090();
        let f16 = d.tc_flops_per_cycle_mode(Dtype::F16, Dtype::F16);
        let bf16 = d.tc_flops_per_cycle_mode(Dtype::Bf16, Dtype::F16);
        let tf32 = d.tc_flops_per_cycle_mode(Dtype::F32, Dtype::F32);
        assert_eq!(f16, bf16);
        assert!(f16 > tf32 && d.tc_flops_per_cycle(Dtype::F32) > tf32);
    }

    #[test]
    fn a100_does_not_halve() {
        let d = DeviceModel::a100();
        assert_eq!(
            d.tc_flops_per_cycle(Dtype::F16),
            d.tc_flops_per_cycle(Dtype::F32)
        );
    }

    #[test]
    fn by_name() {
        assert_eq!(DeviceModel::by_name("rtx3090").unwrap().sms, 82);
        assert!(DeviceModel::by_name("h100").is_none());
    }

    #[test]
    fn bandwidth_concentrates_on_few_sms() {
        let d = DeviceModel::rtx3090();
        assert!(d.hbm_bytes_per_cycle_per_sm(1) > d.hbm_bytes_per_cycle_per_sm(82));
    }
}
