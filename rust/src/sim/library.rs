//! The simulated vendor library (the cuBLAS 11.2 comparator).
//!
//! Same device and kernel model as the generated kernels, but driven by a
//! library-style configuration: a fixed tile-selection heuristic table, a
//! deep (5-stage) software pipeline, and hand-scheduled-SASS compute
//! efficiency.  The table encodes the behaviours the paper observed when
//! profiling cuBLAS:
//!
//! * §4.1 — cuBLAS leans on large reuse-friendly tiles even for small
//!   problems, so small sizes under-occupy the device and the generated
//!   kernels (free to pick 64^3 tiles) win there;
//! * §4.2 — for fp16 the library keeps 128x128x32 even at sizes where
//!   128x256x32 is better (observed at N=11264) and is "not well-tuned for
//!   all problem sizes" beyond N=8848 — modeled as a size-bucketed tile
//!   table with a sub-optimal plateau and bucket-to-bucket jitter.

use crate::schedule::{Dtype, Schedule};
use super::device::DeviceModel;
use super::model::{simulate_with_eff, SimResult};

/// Tensor-pipe efficiency of hand-scheduled SASS (Table 1: "best").
pub const LIBRARY_COMPUTE_EFF: f64 = 0.99;

/// The library's tile-selection heuristic.  Returns (tile_tb, tile_warp).
pub fn library_tile_choice(
    m: usize,
    n: usize,
    k: usize,
    acc: Dtype,
) -> ((usize, usize, usize), (usize, usize, usize)) {
    let size = m.max(n).max(k);
    match acc {
        Dtype::F32 => {
            // Mixed precision: the library is broadly well-tuned, but its
            // smallest kernel is 128x128 (no 64^3 tile in the heuristic),
            // which under-occupies small problems.
            if size <= 3072 {
                ((128, 128, 32), (64, 32, 32))
            } else {
                ((128, 128, 64), (64, 32, 32))
            }
        }
        Dtype::F16 | Dtype::Bf16 => {
            if size <= 4096 {
                ((128, 128, 32), (64, 32, 32))
            } else if size <= 8848 {
                ((128, 128, 64), (64, 32, 32))
            } else {
                // Beyond 8848 the paper profiles inconsistent choices:
                // the heuristic sticks to 128x128x32 (observed at 11264)
                // and some size buckets fall onto an even narrower kernel.
                match (size / 256) % 3 {
                    0 => ((64, 256, 32), (32, 64, 32)),
                    1 => ((128, 128, 32), (64, 32, 32)),
                    // 11264/256 = 44 -> bucket 2: the paper's profiled
                    // 128x128x32 choice lands here.
                    _ => ((128, 128, 32), (64, 32, 32)),
                }
            }
        }
    }
}

/// Simulate the library's kernel for a problem.
pub fn simulate_library(
    m: usize,
    n: usize,
    k: usize,
    acc: Dtype,
    d: &DeviceModel,
) -> SimResult {
    let (tb, warp) = library_tile_choice(m, n, k, acc);
    let mut s = Schedule::optimized(m, n, k, acc, tb, warp)
        .or_else(|_| {
            // Problem not divisible by the library tile: the library pads
            // internally; model with the largest dividing fallback tile.
            Schedule::optimized(m, n, k, acc, (64, 64, 32), (32, 32, 32))
        })
        .unwrap_or_else(|_| {
            Schedule::optimized(m, n, k, acc, (32, 32, 32), (16, 16, 16)).unwrap()
        });
    s.name = format!("cublas_like_m{m}n{n}k{k}_{}", acc.name());
    // Library kernels use deep pipelining (the paper profiled 5 stages).
    s.pipeline_stages = 5;
    let mut r = simulate_with_eff(&s, d, LIBRARY_COMPUTE_EFF);
    r.name = s.name;
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d() -> DeviceModel {
        DeviceModel::rtx3090()
    }

    #[test]
    fn tile_table_is_suboptimal_at_11264_f16() {
        // the paper's §4.2 observation, verbatim
        let (tb, _) = library_tile_choice(11264, 11264, 11264, Dtype::F16);
        assert_eq!(tb, (128, 128, 32));
    }

    #[test]
    fn mixed_precision_is_consistent_but_small_sizes_underoccupy() {
        let small = simulate_library(1024, 1024, 1024, Dtype::F32, &d());
        let large = simulate_library(8192, 8192, 8192, Dtype::F32, &d());
        assert!(large.tflops > small.tflops);
        // 64 blocks of 128x128 tiles on 82 SMs -> visible occupancy dip
        assert!(small.occupancy.active_sms < 82);
    }

    #[test]
    fn fp16_large_sizes_jitter() {
        // neighbouring sizes in the >8848 regime can differ measurably
        let ts: Vec<f64> = [9216usize, 9472, 9728]
            .iter()
            .map(|&s| simulate_library(s, s, s, Dtype::F16, &d()).tflops)
            .collect();
        let max = ts.iter().cloned().fold(f64::MIN, f64::max);
        let min = ts.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min > 1.05, "expected >5% jitter, got {ts:?}");
    }

    #[test]
    fn library_beats_generated_slightly_on_large_mixed() {
        use super::super::model::simulate;
        let lib = simulate_library(8192, 8192, 8192, Dtype::F32, &d());
        let ours = simulate(
            &Schedule::optimized(8192, 8192, 8192, Dtype::F32,
                                 (128, 128, 64), (64, 32, 32)).unwrap(),
            &d(),
        );
        let ratio = ours.tflops / lib.tflops;
        // paper: "within 2-8% of cuBLAS" on large sizes
        assert!(ratio > 0.90 && ratio < 1.0, "ratio {ratio}");
    }

    #[test]
    fn indivisible_problem_falls_back() {
        let r = simulate_library(96, 96, 96, Dtype::F32, &d());
        assert!(r.tflops > 0.0);
    }
}
