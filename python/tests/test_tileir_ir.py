"""Unit tests for tile-IR core structures (ir.py)."""

import numpy as np
import pytest

from compile.tileir.ir import (
    AffineExpr,
    For,
    Load,
    MemRef,
    Module,
    Store,
    WmmaLoad,
    clone_with_fresh_names,
    dtype_bytes,
    fresh_name,
    rename_values,
    subst_exprs,
)


class TestAffineExpr:
    def test_var_and_const(self):
        e = AffineExpr.var("%i") + 5
        assert e.eval({"%i": 3}) == 8

    def test_add_merges_terms(self):
        e = AffineExpr.var("%i") + AffineExpr.var("%i")
        assert e.coeff("%i") == 2

    def test_add_cancels_to_zero(self):
        e = AffineExpr.var("%i") - AffineExpr.var("%i")
        assert e.is_const() and e.const == 0

    def test_sub(self):
        e = (AffineExpr.var("%i") + 10) - AffineExpr.var("%j")
        assert e.eval({"%i": 4, "%j": 3}) == 11

    def test_sub_const(self):
        e = AffineExpr.var("%i") - 4
        assert e.eval({"%i": 10}) == 6

    def test_scaled(self):
        e = (AffineExpr.var("%i") + 2).scaled(3)
        assert e.eval({"%i": 1}) == 9

    def test_subst_var_to_sum(self):
        e = AffineExpr.var("%i") + AffineExpr.var("%k")
        e2 = e.subst({"%i": AffineExpr.var("%i") + AffineExpr.var("%ii")})
        assert e2.eval({"%i": 1, "%ii": 2, "%k": 4}) == 7

    def test_subst_const(self):
        e = AffineExpr.var("%i").scaled(2) + 1
        assert e.subst_const("%i", 5).const == 11

    def test_subst_keeps_other_vars(self):
        e = AffineExpr.var("%i") + AffineExpr.var("%j")
        e2 = e.subst_const("%i", 0)
        assert e2.vars() == ("%j",)

    def test_eval_missing_var_raises(self):
        with pytest.raises(KeyError):
            AffineExpr.var("%i").eval({})

    def test_repr_stable(self):
        e = AffineExpr.var("%i") - AffineExpr.var("%j") + 4
        # rendering is used by printer golden tests; keep it deterministic
        assert repr(e) == repr(AffineExpr.var("%i") - AffineExpr.var("%j") + 4)

    def test_hashable(self):
        assert hash(AffineExpr.var("%i")) == hash(AffineExpr.var("%i"))


class TestMemRef:
    def test_lead_dim_unpadded(self):
        m = MemRef("%A", (128, 64), "f16")
        assert m.lead_dim == 64
        assert m.phys_shape == (128, 64)

    def test_lead_dim_padded(self):
        m = MemRef("%a_smem", (128, 64), "f16", space="shared", lead_pad=8)
        assert m.lead_dim == 72
        assert m.phys_shape == (128, 72)

    def test_size_bytes_matches_paper_listing2(self):
        a = MemRef("%a_smem", (128, 64), "f16", space="shared", lead_pad=8)
        b = MemRef("%b_smem", (64, 128), "f16", space="shared", lead_pad=8)
        assert a.size_bytes() + b.size_bytes() == (128 * 72 + 64 * 136) * 2

    def test_dtype_bytes(self):
        assert dtype_bytes("f16") == 2
        assert dtype_bytes("bf16") == 2
        assert dtype_bytes("f32") == 4

    def test_type_str_spaces(self):
        assert "3" in MemRef("%s", (4, 4), "f16", space="shared").type_str()
        assert MemRef("%g", (4, 4), "f32").type_str() == "memref<4x4xf32>"


class TestForLoop:
    def test_trip_count(self):
        loop = For("%i", AffineExpr.cst(0), AffineExpr.cst(128), 32)
        assert loop.trip_count() == 4

    def test_trip_count_with_env(self):
        loop = For(
            "%c", AffineExpr.var("%k"), AffineExpr.var("%k") + 64, 16
        )
        assert loop.trip_count({"%k": 256}) == 4

    def test_clone_is_deep(self):
        inner = For("%j", AffineExpr.cst(0), AffineExpr.cst(4), 1)
        outer = For("%i", AffineExpr.cst(0), AffineExpr.cst(4), 1, [inner])
        clone = outer.clone()
        clone.body[0].step = 2
        assert inner.step == 1


class TestModuleTraversal:
    def _mod(self):
        mod = Module(name="t")
        a = mod.add_memref(MemRef("%A", (8, 8), "f32"), role="A")
        k = For("%k", AffineExpr.cst(0), AffineExpr.cst(8), 1,
                [Load(fresh_name("x"), a, (AffineExpr.var("%i"), AffineExpr.var("%k")))],
                attrs={"role": "main_k"})
        i = For("%i", AffineExpr.cst(0), AffineExpr.cst(8), 1, [k],
                attrs={"role": "block_i"})
        mod.body = [i]
        return mod

    def test_walk_visits_nested(self):
        mod = self._mod()
        kinds = [type(op).__name__ for op in mod.walk()]
        assert kinds == ["For", "For", "Load"]

    def test_find_loops_by_attr(self):
        mod = self._mod()
        assert len(mod.find_loops(role="main_k")) == 1
        assert mod.find_loops(role="nonexistent") == []

    def test_loop_nest(self):
        mod = self._mod()
        nest = mod.loop_nest()
        assert [l.iv for l in nest] == ["%i", "%k"]


class TestSubstAndRename:
    def test_subst_exprs_recurses_into_loops(self):
        a = MemRef("%A", (8, 8), "f32")
        ld = Load("%x", a, (AffineExpr.var("%i"), AffineExpr.cst(0)))
        loop = For("%j", AffineExpr.var("%i"), AffineExpr.var("%i") + 4, 1, [ld])
        subst_exprs(loop, {"%i": AffineExpr.cst(3)})
        assert loop.lb.const == 3
        assert ld.idxs[0].const == 3

    def test_rename_values(self):
        a = MemRef("%A", (8, 8), "f32")
        ld = Load("%x", a, (AffineExpr.cst(0), AffineExpr.cst(0)))
        st = Store("%x", a, (AffineExpr.cst(1), AffineExpr.cst(1)))
        rename_values(ld, {"%x": "%y"})
        rename_values(st, {"%x": "%y"})
        assert ld.result == "%y" and st.value == "%y"

    def test_clone_with_fresh_names_no_collision(self):
        a = MemRef("%A", (8, 8), "f32")
        ld = Load("%x", a, (AffineExpr.cst(0), AffineExpr.cst(0)))
        st = Store("%x", a, (AffineExpr.cst(1), AffineExpr.cst(1)))
        clones = clone_with_fresh_names([ld, st], "u0")
        assert clones[0].result == "%x_u0"
        assert clones[1].value == "%x_u0"
        assert ld.result == "%x"  # original untouched

    def test_fresh_names_unique(self):
        names = {fresh_name("v") for _ in range(100)}
        assert len(names) == 100
