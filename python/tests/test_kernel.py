"""L1 correctness: generated Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for everything the Rust runtime later
executes: every optimization level, every precision mode, fused epilogues,
and a deterministic sweep over shapes/tiles/dtypes (hypothesis is not in
the offline environment, so the sweep is a fixed parametrized sample of
the same space).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.tileir import PipelineConfig
from compile.kernels import (
    emit_kernel,
    generate_matmul,
    generate_matmul_with_schedule,
    hand_optimized_matmul,
    matmul_bias_ref,
    matmul_bias_relu_ref,
    matmul_ref,
)

SMALL = dict(tile_tb=(32, 32, 32), tile_warp=(16, 16, 16))


def rand_inputs(m, n, k, dtype_in="f16", dtype_acc="f32", seed=0, bias=False):
    rng = np.random.default_rng(seed)
    ind = {"f16": np.float16, "f32": np.float32}[dtype_in]
    accd = {"f16": np.float16, "f32": np.float32}[dtype_acc]
    a = rng.standard_normal((m, k)).astype(ind)
    b = rng.standard_normal((k, n)).astype(ind)
    c = rng.standard_normal((m, n)).astype(accd)
    if bias:
        return a, b, c, rng.standard_normal((n,)).astype(accd)
    return a, b, c


def tol(dtype_acc):
    # True stepwise f16 accumulation (what the naive/rank-1 kernels do)
    # diverges from the oracle's single-rounding matmul by O(sqrt(K)*eps);
    # the bound below covers K <= 128 with margin.  f32 accumulation paths
    # stay tight.
    return dict(rtol=1e-1, atol=1e-1) if dtype_acc == "f16" else dict(rtol=2e-5, atol=2e-5)


class TestOptLevels:
    @pytest.mark.parametrize("level", range(8))
    def test_level_matches_ref_mixed_precision(self, level):
        m = n = k = 64
        cfg = PipelineConfig.opt_level(level, m=m, n=n, k=k, **SMALL)
        f = generate_matmul(cfg)
        a, b, c = rand_inputs(m, n, k)
        got = np.asarray(f(a, b, c))
        ref = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)))
        np.testing.assert_allclose(got, ref, **tol("f32"))

    @pytest.mark.parametrize("level", [0, 3, 7])
    def test_level_matches_ref_half_precision(self, level):
        m = n = k = 64
        cfg = PipelineConfig.opt_level(
            level, m=m, n=n, k=k, dtype_acc="f16", **SMALL
        )
        f = generate_matmul(cfg)
        a, b, c = rand_inputs(m, n, k, dtype_acc="f16")
        got = np.asarray(f(a, b, c))
        ref = np.asarray(
            matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), "f16")
        )
        np.testing.assert_allclose(got, ref, **tol("f16"))

    def test_output_dtype_follows_accumulator(self):
        cfg = PipelineConfig(m=64, n=64, k=64, **SMALL)
        f = generate_matmul(cfg)
        a, b, c = rand_inputs(64, 64, 64)
        assert f(a, b, c).dtype == jnp.float32
        cfg16 = PipelineConfig(m=64, n=64, k=64, dtype_acc="f16", **SMALL)
        f16 = generate_matmul(cfg16)
        a, b, c = rand_inputs(64, 64, 64, dtype_acc="f16")
        assert f16(a, b, c).dtype == jnp.float16

    def test_rectangular_problem(self):
        m, n, k = 32, 96, 64
        cfg = PipelineConfig(m=m, n=n, k=k, **SMALL)
        f = generate_matmul(cfg)
        a, b, c = rand_inputs(m, n, k)
        got = np.asarray(f(a, b, c))
        ref = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)))
        np.testing.assert_allclose(got, ref, **tol("f32"))

    def test_paper_warp_tile_aspect(self):
        # 32x16 warp tile (the paper's 64x32 aspect) on a 128 problem
        cfg = PipelineConfig(
            m=128, n=128, k=128, tile_tb=(64, 64, 32), tile_warp=(32, 16, 16)
        )
        f = generate_matmul(cfg)
        a, b, c = rand_inputs(128, 128, 128, seed=3)
        got = np.asarray(f(a, b, c))
        ref = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)))
        np.testing.assert_allclose(got, ref, **tol("f32"))

    def test_c_is_accumulated_not_overwritten(self):
        cfg = PipelineConfig(m=32, n=32, k=32, tile_tb=(32, 32, 32),
                             tile_warp=(16, 16, 16), latency_hiding=False)
        f = generate_matmul(cfg)
        a, b, _ = rand_inputs(32, 32, 32)
        c = np.full((32, 32), 100.0, dtype=np.float32)
        got = np.asarray(f(a, b, c))
        assert got.mean() > 50  # C contributed


class TestFusedEpilogues:
    def test_bias(self):
        m = n = k = 64
        cfg = PipelineConfig(m=m, n=n, k=k, epilogue="bias", **SMALL)
        f = generate_matmul(cfg)
        a, b, c, bias = rand_inputs(m, n, k, bias=True)
        got = np.asarray(f(a, b, c, bias))
        ref = np.asarray(
            matmul_bias_ref(
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), jnp.asarray(bias)
            )
        )
        np.testing.assert_allclose(got, ref, **tol("f32"))

    def test_bias_relu(self):
        m = n = k = 64
        cfg = PipelineConfig(m=m, n=n, k=k, epilogue="bias_relu", **SMALL)
        f = generate_matmul(cfg)
        a, b, c, bias = rand_inputs(m, n, k, bias=True, seed=1)
        got = np.asarray(f(a, b, c, bias))
        ref = np.asarray(
            matmul_bias_relu_ref(
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), jnp.asarray(bias)
            )
        )
        np.testing.assert_allclose(got, ref, **tol("f32"))
        assert (got >= 0).all()

    def test_fused_epilogue_on_unhoisted_level(self):
        # epilogue must also work on the pre-hoisting structure (level 3)
        m = n = k = 64
        cfg = PipelineConfig.opt_level(3, m=m, n=n, k=k, epilogue="bias", **SMALL)
        f = generate_matmul(cfg)
        a, b, c, bias = rand_inputs(m, n, k, bias=True, seed=2)
        got = np.asarray(f(a, b, c, bias))
        ref = np.asarray(
            matmul_bias_ref(
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), jnp.asarray(bias)
            )
        )
        np.testing.assert_allclose(got, ref, **tol("f32"))

    def test_naive_fused(self):
        m = n = k = 32
        cfg = PipelineConfig.opt_level(0, m=m, n=n, k=k, epilogue="bias_relu", **SMALL)
        f = generate_matmul(cfg)
        a, b, c, bias = rand_inputs(m, n, k, bias=True)
        got = np.asarray(f(a, b, c, bias))
        ref = np.asarray(
            matmul_bias_relu_ref(
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), jnp.asarray(bias)
            )
        )
        np.testing.assert_allclose(got, ref, **tol("f32"))


class TestHandOptimized:
    def test_matches_ref(self):
        m = n = k = 128
        h = hand_optimized_matmul(m, n, k, tile=(64, 64, 32))
        a, b, c = rand_inputs(m, n, k, seed=4)
        got = np.asarray(h(a, b, c))
        ref = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)))
        np.testing.assert_allclose(got, ref, **tol("f32"))

    def test_rejects_bad_tile(self):
        with pytest.raises(ValueError):
            hand_optimized_matmul(100, 64, 64, tile=(64, 64, 32))


class TestScheduleContract:
    def test_emitted_kernel_carries_schedule(self):
        cfg = PipelineConfig(m=64, n=64, k=64, **SMALL)
        f, sched = generate_matmul_with_schedule(cfg)
        assert f.schedule is sched
        assert sched.grid == (2, 2)

    def test_emit_rejects_non_divisible(self):
        from compile.tileir.schedule import Schedule

        sched = Schedule(
            name="bad", m=100, n=64, k=64, dtype_in="f16", dtype_acc="f32",
            epilogue="none", opt_level=7, tiling=True, shared_mem=True,
            wmma=True, unroll_hoist=True, latency_hiding=True, padding=True,
            vectorize=True, tile_tb=(32, 32, 32), tile_warp=(16, 16, 16),
            wmma_mnk=(16, 16, 16), pad_factor=8, vec_width=8,
            pipeline_stages=2, grid=(3, 2), warps_per_block=(2, 2),
            threads_per_block=128, smem_bytes=0, accumulators_per_warp=1,
            barriers_per_iteration=2,
        )
        with pytest.raises(Exception):
            emit_kernel(sched)


# Deterministic sweep over shapes (multiples of the fragment), warp tiles,
# dtypes, and levels — a fixed sample of the space the original
# property-based sweep drew from.
_SWEEP = [
    # (mi, ni, ki, warp, dtype_acc, level)
    (1, 1, 2, (16, 16, 16), "f32", 0),
    (2, 1, 2, (32, 32, 32), "f32", 1),
    (1, 2, 3, (32, 16, 16), "f32", 2),
    (2, 2, 2, (16, 16, 16), "f16", 3),
    (3, 1, 2, (32, 32, 32), "f16", 4),
    (1, 3, 2, (16, 16, 16), "f32", 5),
    (2, 3, 3, (32, 16, 16), "f32", 6),
    (3, 3, 2, (32, 32, 32), "f16", 7),
    (1, 1, 3, (32, 16, 16), "f16", 0),
    (3, 2, 2, (16, 16, 16), "f32", 7),
]


class TestSweep:
    @pytest.mark.parametrize("mi,ni,ki,warp,dtype_acc,level", _SWEEP)
    def test_generated_kernel_matches_ref(self, mi, ni, ki, warp, dtype_acc, level):
        tb = (32, 32, 32)
        m, n, k = 32 * mi, 32 * ni, 32 * ki
        cfg = PipelineConfig.opt_level(
            level, m=m, n=n, k=k, tile_tb=tb, tile_warp=warp, dtype_acc=dtype_acc
        )
        f = generate_matmul(cfg)
        a, b, c = rand_inputs(m, n, k, dtype_acc=dtype_acc, seed=m * n + k + level)
        got = np.asarray(f(a, b, c))
        ref = np.asarray(
            matmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), dtype_acc)
        )
        np.testing.assert_allclose(got, ref, **tol(dtype_acc))
