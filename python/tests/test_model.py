"""L2 tests: model graphs, fused-vs-unfused agreement, transformer layer."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.model import (
    matmul_baseline,
    matmul_variant,
    transformer_layer,
    transformer_layer_inputs,
    unfused_epilogue,
)
from compile.tileir import PipelineConfig

SMALL = dict(tile_tb=(32, 32, 32), tile_warp=(16, 16, 16))


def rand(shape, dtype=np.float32, seed=0):
    return np.random.default_rng(seed).standard_normal(shape).astype(dtype)


class TestMatmulGraphs:
    def test_variant_matches_baseline(self):
        m = n = k = 64
        cfg = PipelineConfig(m=m, n=n, k=k, **SMALL)
        gen = matmul_variant(cfg)
        base = matmul_baseline(m, n, k)
        a, b, c = rand((m, k), seed=1), rand((k, n), seed=2), rand((m, n), seed=3)
        got = np.asarray(gen(a.astype(np.float16), b.astype(np.float16), c)[0])
        want = np.asarray(base(a, b, c)[0])
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_fused_matches_unfused(self):
        m = n = k = 64
        cfg = PipelineConfig(m=m, n=n, k=k, epilogue="bias_relu", **SMALL)
        fused = matmul_variant(cfg)
        unfused = unfused_epilogue(PipelineConfig(m=m, n=n, k=k, **SMALL))
        a, b, c = rand((m, k), seed=1), rand((k, n), seed=2), rand((m, n), seed=3)
        bias = rand((n,), seed=4)
        got = np.asarray(
            fused(a.astype(np.float16), b.astype(np.float16), c, bias)[0]
        )
        want = np.asarray(unfused(a, b, c, bias)[0])
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        assert (got >= 0).all()

    def test_unfused_has_barrier(self):
        # the optimization barrier keeps the comparison honest in the HLO
        fn = unfused_epilogue(PipelineConfig(m=64, n=64, k=64, **SMALL))
        shapes = [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 3 + [
            jax.ShapeDtypeStruct((64,), jnp.float32)
        ]
        hlo = jax.jit(fn).lower(*shapes).compiler_ir("stablehlo")
        assert "optimization_barrier" in str(hlo)


class TestTransformerLayer:
    DIMS = dict(seq=64, d_model=64, d_ff=128)

    def _layer_and_inputs(self):
        layer = transformer_layer(
            **self.DIMS, n_heads=4, tile_tb=(32, 32, 32), tile_warp=(16, 16, 16)
        )
        shapes = transformer_layer_inputs(**self.DIMS)
        rng = np.random.default_rng(0)
        args = [
            (rng.standard_normal(s.shape) * 0.1).astype(np.float32) for s in shapes
        ]
        return layer, args

    def _ref_layer(self, x, w_qkv, w_out, w_up, b_up, w_dn, b_dn, n_heads=4):
        """Pure-numpy reference (f32 throughout; tolerance covers f16 GEMMs)."""
        seq, d_model = x.shape
        d_head = d_model // n_heads
        qkv = x @ w_qkv
        q, k, v = np.split(qkv, 3, axis=1)

        def heads(t):
            return t.reshape(seq, n_heads, d_head).transpose(1, 0, 2)

        qh, kh, vh = heads(q), heads(k), heads(v)
        scores = np.einsum("hqd,hkd->hqk", qh, kh) / np.sqrt(d_head)
        probs = np.exp(scores - scores.max(-1, keepdims=True))
        probs /= probs.sum(-1, keepdims=True)
        ctx = np.einsum("hqk,hkd->hqd", probs, vh)
        ctx = ctx.transpose(1, 0, 2).reshape(seq, d_model)
        h = x + ctx @ w_out
        mu, var = h.mean(-1, keepdims=True), h.var(-1, keepdims=True)
        hn = (h - mu) / np.sqrt(var + 1e-5)
        up = np.maximum(hn @ w_up + b_up, 0)
        return h + up @ w_dn + b_dn

    def test_matches_reference(self):
        layer, args = self._layer_and_inputs()
        got = np.asarray(layer(*args)[0])
        want = self._ref_layer(*[np.asarray(a, np.float64) for a in args])
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)

    def test_output_shape_and_dtype(self):
        layer, args = self._layer_and_inputs()
        out = layer(*args)[0]
        assert out.shape == (self.DIMS["seq"], self.DIMS["d_model"])
        assert out.dtype == jnp.float32

    def test_rejects_non_tile_multiple_dims(self):
        with pytest.raises(ValueError):
            transformer_layer(seq=100, d_model=64, d_ff=128,
                              tile_tb=(32, 32, 32), tile_warp=(16, 16, 16))

    def test_lowerable(self):
        layer, _ = self._layer_and_inputs()
        shapes = transformer_layer_inputs(**self.DIMS)
        jax.jit(layer).lower(*shapes)  # must not raise
