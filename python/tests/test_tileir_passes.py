"""Pass-by-pass tests: structure checks + interpreter equivalence.

Every pass is validated two ways: (a) the structural postcondition the
paper describes (tile steps, buffer shapes, barrier placement, iter_args,
peeled stages), and (b) semantic equivalence against numpy matmul through
the tile-IR interpreter.
"""

import numpy as np
import pytest

from compile.tileir import passes as P
from compile.tileir.builder import build_naive_matmul
from compile.tileir.interp import run_matmul_module
from compile.tileir.ir import Barrier, For, VecLoad, VecStore, WmmaLoad, WmmaMma, WmmaStore, Yield
from compile.tileir.pipeline import OPT_ORDER, PipelineConfig, PipelineError, run_pipeline
from compile.tileir.printer import print_module
from compile.tileir.schedule import ScheduleError, extract_schedule


SMALL = dict(m=64, n=64, k=64, tile_tb=(32, 32, 32), tile_warp=(16, 16, 16))


def small_mod(**over):
    params = {**SMALL, **over}
    mod = build_naive_matmul(params["m"], params["n"], params["k"])
    mod.meta.update(
        {
            "tile_tb": params["tile_tb"],
            "tile_warp": params["tile_warp"],
            "pad_factor": 8,
            "vec_width": 8,
        }
    )
    return mod


def check_semantics(mod, m=64, n=64, k=64, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n))
    c = rng.standard_normal((m, n))
    got = run_matmul_module(mod, a, b, c.copy())
    np.testing.assert_allclose(got, a @ b + c, rtol=1e-10, atol=1e-10)


class TestTiling:
    def test_nest_depth_is_nine(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        assert len(mod.loop_nest()) == 9

    def test_steps_follow_tiles(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        nest = mod.loop_nest()
        assert [l.step for l in nest] == [32, 32, 32, 16, 16, 16, 1, 1, 1]

    def test_roles_assigned(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        roles = [l.attrs["role"] for l in mod.loop_nest()]
        assert roles == [
            "block_i", "block_j", "main_k",
            "warp_i", "warp_j", "warp_k",
            "frag_i", "frag_j", "frag_k",
        ]

    def test_semantics_preserved(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        check_semantics(mod)

    def test_semantics_rectangular(self):
        mod = build_naive_matmul(32, 64, 96)
        mod.meta.update({"tile_tb": (32, 32, 32), "tile_warp": (16, 16, 16)})
        P.two_level_tiling(mod)
        check_semantics(mod, 32, 64, 96)

    def test_rejects_non_divisible(self):
        mod = build_naive_matmul(48, 64, 64)
        mod.meta.update({"tile_tb": (32, 32, 32), "tile_warp": (16, 16, 16)})
        with pytest.raises(P.tiling.TilingError):
            P.two_level_tiling(mod)

    def test_rejects_warp_not_dividing_tb(self):
        mod = small_mod(tile_warp=(24, 16, 16))
        with pytest.raises(P.tiling.TilingError):
            P.two_level_tiling(mod)


class TestSharedBuffers:
    def _tiled(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        return mod

    def test_buffers_created_with_tile_shapes(self):
        mod = self._tiled()
        P.create_shared_buffers(mod)
        assert mod.roles["a_smem"].shape == (32, 32)
        assert mod.roles["b_smem"].shape == (32, 32)
        assert mod.roles["a_smem"].space == "shared"

    def test_copy_nests_placed_in_main_k(self):
        mod = self._tiled()
        P.create_shared_buffers(mod)
        k = mod.find_loops(role="main_k")[0]
        roles = [op.attrs.get("role") for op in k.body if isinstance(op, For)]
        assert roles[:2] == ["copyB", "copyA"]  # paper order (Listing 2)

    def test_compute_loads_rebased_to_smem(self):
        mod = self._tiled()
        P.create_shared_buffers(mod)
        frag_k = mod.find_loops(role="frag_k")[0]
        from compile.tileir.ir import Load

        loads = [op for op in frag_k.body if isinstance(op, Load)]
        srcs = {op.memref.name for op in loads}
        assert "%a_smem" in srcs and "%b_smem" in srcs
        assert "%C" in {op.memref.name for op in loads}  # C stays global

    def test_semantics_preserved(self):
        mod = self._tiled()
        P.create_shared_buffers(mod)
        check_semantics(mod)

    def test_requires_tiling(self):
        mod = small_mod()
        with pytest.raises(P.buffers.BufferError):
            P.create_shared_buffers(mod)


class TestPadding:
    def _staged(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        return mod

    def test_pads_lead_dim_only(self):
        mod = self._staged()
        P.pad_shared_buffers(mod, 8)
        assert mod.roles["a_smem"].phys_shape == (32, 40)
        assert mod.roles["a_smem"].shape == (32, 32)

    def test_paper_alignment_constraint(self):
        # f16 requires multiples of 8 (128-bit WMMA alignment)
        mod = self._staged()
        with pytest.raises(P.padding.PaddingError):
            P.pad_shared_buffers(mod, 4)

    def test_semantics_with_padding(self):
        mod = self._staged()
        P.pad_shared_buffers(mod, 8)
        check_semantics(mod)

    def test_requires_buffers(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        with pytest.raises(P.padding.PaddingError):
            P.pad_shared_buffers(mod, 8)


class TestWmma:
    def _staged(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        return mod

    def test_fragment_steps_bumped(self):
        mod = self._staged()
        P.generate_wmma_ops(mod)
        for role in ("frag_i", "frag_j", "frag_k"):
            assert mod.find_loops(role=role)[0].step == 16

    def test_body_is_wmma_sequence(self):
        mod = self._staged()
        P.generate_wmma_ops(mod)
        body = mod.find_loops(role="frag_k")[0].body
        kinds = [type(op).__name__ for op in body]
        assert kinds == ["WmmaLoad", "WmmaLoad", "WmmaLoad", "WmmaMma", "WmmaStore"]
        assert [op.operand for op in body[:3]] == ["AOp", "BOp", "COp"]

    def test_semantics_preserved(self):
        mod = self._staged()
        P.generate_wmma_ops(mod)
        check_semantics(mod)

    def test_works_without_shared_mem(self):
        # ablation config: wmma straight out of global memory
        mod = small_mod()
        P.two_level_tiling(mod)
        P.generate_wmma_ops(mod)
        check_semantics(mod)

    def test_rejects_bad_intrinsic(self):
        mod = self._staged()
        with pytest.raises(P.wmma.WmmaError):
            P.generate_wmma_ops(mod, (24, 16, 16))


class TestPermute:
    def _wmma(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        return mod

    def test_loop_order_matches_paper(self):
        mod = self._wmma()
        P.permute_for_gpu_hierarchy(mod)
        # copies break the perfect nest inside k; check roles down the spine
        i = mod.find_loops(role="block_i")[0]
        j = i.body[0]
        ii = j.body[0]
        jj = ii.body[0]
        k = jj.body[0]
        assert (j.attrs["role"], ii.attrs["role"], jj.attrs["role"], k.attrs["role"]) == (
            "block_j", "warp_i", "warp_j", "main_k",
        )
        kk = [op for op in k.body if isinstance(op, For) and op.attrs["role"] == "warp_k"]
        assert len(kk) == 1
        kkk = kk[0].body[0]
        assert kkk.attrs["role"] == "frag_k"  # outer-product order: k first
        assert kkk.body[0].attrs["role"] == "frag_i"
        assert kkk.body[0].body[0].attrs["role"] == "frag_j"

    def test_copies_stay_in_main_k(self):
        mod = self._wmma()
        P.permute_for_gpu_hierarchy(mod)
        k = mod.find_loops(role="main_k")[0]
        roles = [op.attrs.get("role") for op in k.body if isinstance(op, For)]
        assert "copyA" in roles and "copyB" in roles

    def test_semantics_preserved(self):
        mod = self._wmma()
        P.permute_for_gpu_hierarchy(mod)
        check_semantics(mod)


class TestUnrollHoist:
    def _permuted(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        return mod

    def test_k_loops_carry_iter_args(self):
        mod = self._permuted()
        P.unroll_and_hoist(mod)
        k = mod.find_loops(role="main_k")[0]
        kk = mod.find_loops(role="warp_k")[0]
        # warp tile 16x16 -> 1 accumulator fragment with WMMA m16n16
        assert len(k.iter_args) == 1
        assert len(kk.iter_args) == 1
        assert isinstance(k.body[-1], Yield)
        assert isinstance(kk.body[-1], Yield)

    def test_accumulator_count_paper_config(self):
        mod = build_naive_matmul(256, 256, 128)
        mod.meta.update({"tile_tb": (128, 128, 64), "tile_warp": (64, 32, 32)})
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        P.unroll_and_hoist(mod)
        # paper: 64/16 x 32/16 = 8 accumulators per warp
        assert mod.meta["num_accumulators"] == 8
        k = mod.find_loops(role="main_k")[0]
        assert len(k.iter_args) == 8

    def test_cse_removes_duplicate_fragment_loads(self):
        mod = build_naive_matmul(64, 64, 64)
        mod.meta.update({"tile_tb": (64, 64, 32), "tile_warp": (32, 32, 32)})
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        P.unroll_and_hoist(mod)
        kk = mod.find_loops(role="warp_k")[0]
        loads = [op for op in kk.body if isinstance(op, WmmaLoad)]
        mmas = [op for op in kk.body if isinstance(op, WmmaMma)]
        # 2x2 fragment grid, 2 k-steps: 8 MMAs but only 4 A-frag + 4 B-frag loads
        assert len(mmas) == 8
        assert len([l for l in loads if l.operand == "AOp"]) == 4
        assert len([l for l in loads if l.operand == "BOp"]) == 4
        assert not [l for l in loads if l.operand == "COp"]  # hoisted out

    def test_no_c_traffic_inside_k_loop(self):
        mod = self._permuted()
        P.unroll_and_hoist(mod)
        k = mod.find_loops(role="main_k")[0]

        def c_ops(ops):
            for op in ops:
                if isinstance(op, (WmmaLoad, WmmaStore)) and op.memref.name == "%C":
                    yield op
                if isinstance(op, For):
                    yield from c_ops(op.body)

        assert list(c_ops(k.body)) == []

    def test_hoisted_loads_and_stores_at_warp_level(self):
        mod = self._permuted()
        P.unroll_and_hoist(mod)
        jj = mod.find_loops(role="warp_j")[0]
        assert isinstance(jj.body[0], WmmaLoad) and jj.body[0].operand == "COp"
        assert isinstance(jj.body[-1], WmmaStore)

    def test_semantics_preserved(self):
        mod = self._permuted()
        P.unroll_and_hoist(mod)
        check_semantics(mod)


class TestLatencyHiding:
    def _hoisted(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        P.unroll_and_hoist(mod)
        return mod

    def _complete(self, mod):
        P.split_main_k_loop(mod)
        P.insert_barriers(mod)
        P.decouple_copy_stores(mod)
        return mod

    def test_peeled_stages_exist(self):
        mod = self._complete(self._hoisted())
        stages = {
            op.attrs.get("stage")
            for op in mod.walk()
            if isinstance(op, For) and "stage" in op.attrs
        }
        assert stages == {"prologue", "steady", "epilogue"}

    def test_main_loop_bounds_shrunk(self):
        mod = self._complete(self._hoisted())
        k = mod.find_loops(role="main_k")[0]
        assert k.ub.const == 64 - 32  # K - tbk

    def test_load_store_phases_decoupled(self):
        mod = self._complete(self._hoisted())
        k = mod.find_loops(role="main_k")[0]
        phases = [
            op.attrs.get("phase")
            for op in k.body
            if isinstance(op, For) and "phase" in op.attrs
        ]
        # loads strictly precede stores in the steady-state body
        assert phases == ["load", "load", "store", "store"]

    def test_stage_buffers_created(self):
        mod = self._complete(self._hoisted())
        assert mod.roles["a_stage"].space == "reg"
        assert mod.roles["b_stage"].shape == mod.roles["b_smem"].shape

    def test_semantics_after_decouple(self):
        mod = self._complete(self._hoisted())
        check_semantics(mod)

    def test_semantics_with_more_k_tiles(self):
        mod = build_naive_matmul(32, 32, 128)
        mod.meta.update({"tile_tb": (32, 32, 32), "tile_warp": (16, 16, 16)})
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        P.unroll_and_hoist(mod)
        self._complete(mod)
        check_semantics(mod, 32, 32, 128)

    def test_rejects_single_k_tile(self):
        mod = build_naive_matmul(32, 32, 32)
        mod.meta.update({"tile_tb": (32, 32, 32), "tile_warp": (16, 16, 16)})
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        P.unroll_and_hoist(mod)
        with pytest.raises(P.latency.LatencyError):
            P.split_main_k_loop(mod)

    def test_requires_shared_mem(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        P.unroll_and_hoist(mod)
        with pytest.raises(P.latency.LatencyError):
            P.split_main_k_loop(mod)


class TestBarriers:
    def test_algorithm1_placement(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.insert_barriers(mod)
        k = mod.find_loops(role="main_k")[0]
        kinds = [type(op).__name__ for op in k.body]
        # barrier, copyB, copyA, barrier, compute
        assert kinds[0] == "Barrier"
        assert kinds[3] == "Barrier"

    def test_listing6_placement(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.generate_wmma_ops(mod)
        P.permute_for_gpu_hierarchy(mod)
        P.unroll_and_hoist(mod)
        P.split_main_k_loop(mod)
        P.insert_barriers(mod)
        P.decouple_copy_stores(mod)
        k = mod.find_loops(role="main_k")[0]
        assert isinstance(k.body[0], Barrier)  # top-of-loop barrier
        barrier_count = sum(1 for op in k.body if isinstance(op, Barrier))
        assert barrier_count == 2  # top + before delayed stores
        jj = mod.find_loops(role="warp_j")[0]
        jj_barriers = [op for op in jj.body if isinstance(op, Barrier)]
        assert len(jj_barriers) == 2  # after prologue, before epilogue

    def test_semantics_not_affected(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.insert_barriers(mod)
        check_semantics(mod)


class TestVectorize:
    def _staged(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.pad_shared_buffers(mod, 8)
        return mod

    def test_copy_bodies_become_vector_ops(self):
        mod = self._staged()
        P.vectorize_copies(mod, 8)
        vloads = [op for op in mod.walk() if isinstance(op, VecLoad)]
        vstores = [op for op in mod.walk() if isinstance(op, VecStore)]
        assert len(vloads) == 2 and len(vstores) == 2
        assert all(v.width == 8 for v in vloads)

    def test_inner_step_bumped(self):
        mod = self._staged()
        P.vectorize_copies(mod, 8)
        for nest in mod.find_loops(role="copyA"):
            inner = nest.body[0]
            assert inner.step == 8

    def test_semantics_preserved(self):
        mod = self._staged()
        P.vectorize_copies(mod, 8)
        check_semantics(mod)

    def test_rejects_width_not_dividing_pad(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        # pad of 8 then vectorize by 16: lead_dim 40 % 16 != 0
        P.pad_shared_buffers(mod, 8)
        with pytest.raises(P.vectorize.VectorizeError):
            P.vectorize_copies(mod, 16)

    def test_rejects_non_power_width(self):
        mod = self._staged()
        with pytest.raises(P.vectorize.VectorizeError):
            P.vectorize_copies(mod, 3)


class TestParallelize:
    def test_block_and_warp_mapping(self):
        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        P.extract_and_map_parallel(mod)
        assert mod.find_loops(role="block_i")[0].attrs["parallel"] == "block_y"
        assert mod.find_loops(role="warp_j")[0].attrs["parallel"] == "warp_x"
        assert mod.meta["grid"] == (2, 2)
        assert mod.meta["threads_per_block"] == 4 * 32

    def test_k_loop_not_parallel(self):
        from compile.tileir.passes.parallelize import is_loop_parallel

        mod = small_mod()
        P.two_level_tiling(mod)
        k = mod.find_loops(role="main_k")[0]
        assert not is_loop_parallel(k)

    def test_block_loops_parallel(self):
        from compile.tileir.passes.parallelize import is_loop_parallel

        mod = small_mod()
        P.two_level_tiling(mod)
        P.create_shared_buffers(mod)
        assert is_loop_parallel(mod.find_loops(role="block_i")[0])
        assert is_loop_parallel(mod.find_loops(role="block_j")[0])

    def test_naive_maps_blocks_only(self):
        mod = small_mod()
        P.extract_and_map_parallel(mod)
        assert mod.meta["grid"] == (64, 64)
        assert mod.meta["warps_per_block"] == (1, 1)


class TestPipeline:
    @pytest.mark.parametrize("level", range(8))
    def test_all_ablation_levels_verify(self, level):
        cfg = PipelineConfig.opt_level(level, **SMALL)
        run_pipeline(cfg, verify=True)

    def test_full_pipeline_snapshot_names(self):
        cfg = PipelineConfig(**SMALL)
        res = run_pipeline(cfg, capture_snapshots=True)
        assert "build_naive" in res.snapshots
        assert "decouple_copy_stores" in res.snapshots
        assert res.passes_run[-1] == "extract_and_map_parallel"

    def test_dependency_enforcement(self):
        with pytest.raises(PipelineError):
            PipelineConfig(**SMALL, tiling=False).validate()

    def test_latency_requires_hoist(self):
        with pytest.raises(PipelineError):
            PipelineConfig(**SMALL, unroll_hoist=False).validate()

    def test_non_divisible_problem_rejected(self):
        with pytest.raises(PipelineError):
            PipelineConfig(m=100, n=64, k=64, tile_tb=(32, 32, 32),
                           tile_warp=(16, 16, 16)).validate()

    def test_variant_name_roundtrips_opts(self):
        cfg = PipelineConfig.opt_level(3, **SMALL)
        assert "_o1110000" in cfg.variant_name()

    def test_level_of_cumulative_configs(self):
        for lvl in range(8):
            assert PipelineConfig.opt_level(lvl, **SMALL).level() == lvl

    def test_rectangular_problem(self):
        cfg = PipelineConfig(m=32, n=64, k=96, tile_tb=(32, 32, 32),
                             tile_warp=(16, 16, 16))
        run_pipeline(cfg, verify=True)

    def test_f16_accumulate_variant(self):
        cfg = PipelineConfig(**SMALL, dtype_acc="f16")
        run_pipeline(cfg, verify=True)


class TestSchedule:
    def test_paper_config_matches_listing2(self):
        cfg = PipelineConfig(m=8192, n=8192, k=8192)
        res = run_pipeline(cfg)
        s = extract_schedule(res.module, cfg)
        assert s.smem_bytes == (128 * 72 + 64 * 136) * 2
        assert s.accumulators_per_warp == 8
        assert s.threads_per_block == 256
        assert s.grid == (64, 64)
        assert s.pipeline_stages == 2

    def test_flops(self):
        cfg = PipelineConfig(**SMALL)
        res = run_pipeline(cfg)
        s = extract_schedule(res.module, cfg)
        assert s.flops() == 2 * 64 ** 3

    def test_unpadded_when_toggle_off(self):
        cfg = PipelineConfig.opt_level(5, **SMALL)  # padding not yet enabled
        res = run_pipeline(cfg)
        s = extract_schedule(res.module, cfg)
        assert s.pad_factor == 0
        assert s.smem_bytes == (32 * 32 + 32 * 32) * 2

    def test_json_dict_is_plain(self):
        import json

        cfg = PipelineConfig(**SMALL)
        res = run_pipeline(cfg)
        s = extract_schedule(res.module, cfg)
        json.dumps(s.to_json_dict())  # must not raise

    def test_incomplete_module_rejected(self):
        cfg = PipelineConfig(**SMALL)
        mod = build_naive_matmul(64, 64, 64)
        with pytest.raises(ScheduleError):
            extract_schedule(mod, cfg)


class TestPrinter:
    def test_naive_listing_shape(self):
        mod = build_naive_matmul(8192, 8192, 8192)
        text = print_module(mod)
        assert "affine.for %i = 0 to 8192" in text
        assert "affine.load %A[%i, %k] : memref<8192x8192xf16>" in text
        assert "fpext" in text

    def test_wmma_listing_shape(self):
        cfg = PipelineConfig(m=8192, n=8192, k=8192)
        res = run_pipeline(cfg, capture_snapshots=True)
        text = res.snapshots["generate_wmma_ops"]
        assert "gpu.subgroup_mma_load_matrix" in text
        assert 'leadDimension = 8192' in text
        assert "gpu.subgroup_mma_compute" in text

    def test_padded_buffer_in_listing(self):
        cfg = PipelineConfig(m=8192, n=8192, k=8192)
        res = run_pipeline(cfg, capture_snapshots=True)
        text = res.snapshots["pad_shared_buffers"]
        # paper Listing 2: memref<128x72xf16, 3> and memref<64x136xf16, 3>
        assert "memref<128x72xf16, 3>" in text
        assert "memref<64x136xf16, 3>" in text

    def test_final_listing_has_barriers_and_iter_args(self):
        cfg = PipelineConfig(m=8192, n=8192, k=8192)
        res = run_pipeline(cfg, capture_snapshots=True)
        text = res.snapshots["extract_and_map_parallel"]
        assert "gpu.barrier" in text
        assert "iter_args" in text
        assert "affine.yield" in text
