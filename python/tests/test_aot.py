"""AOT pipeline tests: HLO text interchange + manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import ArtifactWriter, as_f32_io, to_hlo_text, tile_candidates
from compile.model import matmul_baseline
from compile.tileir import PipelineConfig
from compile.kernels import generate_matmul


class TestHloText:
    def test_lowering_produces_parsable_header(self):
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # f32 at the boundary, f16 inside (the in-graph cast)
        assert "f16" in text

    def test_generated_kernel_lowered_contains_loop(self):
        cfg = PipelineConfig(m=64, n=64, k=64, tile_tb=(32, 32, 32),
                             tile_warp=(16, 16, 16))
        kernel = generate_matmul(cfg)
        fn = as_f32_io(lambda a, b, c: (kernel(a, b, c),))
        shapes = [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 3
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        assert "while" in text  # the interpreted grid loop

    def test_outputs_are_tupled(self):
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        # return_tuple=True: the entry root is a tuple (rust unwraps to_tuple1)
        assert "(f32[32,32]" in text.replace(" ", "")


class TestArtifactWriter:
    def test_writes_file_and_manifest(self, tmp_path):
        w = ArtifactWriter(str(tmp_path))
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        w.lower("t0", fn, shapes, kind="baseline", extra={"m": 32})
        w.finish()
        assert (tmp_path / "t0.hlo.txt").exists()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["version"] == 1
        e = manifest["artifacts"][0]
        assert e["name"] == "t0"
        assert e["kind"] == "baseline"
        assert e["m"] == 32
        assert e["inputs"][0] == {"shape": [32, 32], "dtype": "f32"}
        assert e["outputs"][0] == {"shape": [32, 32], "dtype": "f32"}

    def test_schedule_embedded_for_generated(self, tmp_path):
        from compile.kernels import generate_matmul_with_schedule

        w = ArtifactWriter(str(tmp_path))
        cfg = PipelineConfig(m=64, n=64, k=64, tile_tb=(32, 32, 32),
                             tile_warp=(16, 16, 16))
        kernel, sched = generate_matmul_with_schedule(cfg)
        fn = as_f32_io(lambda a, b, c: (kernel(a, b, c),))
        shapes = [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 3
        w.lower(sched.name, fn, shapes, kind="generated",
                schedule=sched.to_json_dict())
        w.finish()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        s = manifest["artifacts"][0]["schedule"]
        assert s["tile_tb"] == [32, 32, 32]
        assert s["opt_level"] == 7
        assert s["grid"] == [2, 2]


class TestTileCandidates:
    def test_small_sizes_get_small_tiles_only(self):
        assert tile_candidates(256) == [((64, 64, 64), (32, 32, 32))]

    def test_large_sizes_include_paper_tile(self):
        cands = tile_candidates(1024)
        assert ((128, 128, 64), (64, 32, 32)) in cands


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    def _manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")
        return json.load(open(path))

    def test_all_files_exist(self):
        m = self._manifest()
        base = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        for e in m["artifacts"]:
            assert os.path.exists(os.path.join(base, e["file"])), e["name"]

    def test_kinds_cover_every_experiment(self):
        kinds = {e["kind"] for e in self._manifest()["artifacts"]}
        assert {"generated", "baseline", "ablation", "fused", "unfused",
                "hand", "transformer"} <= kinds

    def test_ablation_ladder_complete(self):
        abl = [e for e in self._manifest()["artifacts"] if e["kind"] == "ablation"]
        levels = sorted(e["schedule"]["opt_level"] for e in abl)
        assert levels == list(range(8))

    def test_io_all_f32(self):
        for e in self._manifest()["artifacts"]:
            for s in e["inputs"] + e["outputs"]:
                assert s["dtype"] == "f32", e["name"]
