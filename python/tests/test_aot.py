"""AOT pipeline tests: tensor-program interchange + manifest integrity.

The artifact contract (DESIGN.md §3): one ``*.tprog.json`` program
descriptor per artifact plus a ``manifest.json`` index; HLO text is an
optional provenance side-channel (``--hlo``).
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.aot import (
    TPROG_FORMAT,
    ArtifactWriter,
    as_f32_io,
    gemm_program,
    program_input_shapes,
    program_output_shapes,
    tile_candidates,
    to_hlo_text,
    transformer_program,
)
from compile.model import matmul_baseline
from compile.tileir import PipelineConfig
from compile.kernels import generate_matmul


class TestHloText:
    def test_lowering_produces_parsable_header(self):
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # f32 at the boundary, f16 inside (the in-graph cast)
        assert "f16" in text

    def test_generated_kernel_lowered_contains_loop(self):
        cfg = PipelineConfig(m=64, n=64, k=64, tile_tb=(32, 32, 32),
                             tile_warp=(16, 16, 16))
        kernel = generate_matmul(cfg)
        fn = as_f32_io(lambda a, b, c: (kernel(a, b, c),))
        shapes = [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 3
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        assert "while" in text  # the interpreted grid loop

    def test_outputs_are_tupled(self):
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        # return_tuple=True: the entry root is a tuple
        assert "(f32[32,32]" in text.replace(" ", "")


class TestProgramDescriptors:
    def test_gemm_contract_shapes(self):
        p = gemm_program(64, 32, 16)
        assert program_input_shapes(p) == [[64, 16], [16, 32], [64, 32]]
        assert program_output_shapes(p) == [[64, 32]]
        p = gemm_program(8, 8, 8, epilogue="bias_relu")
        assert program_input_shapes(p)[-1] == [8]

    def test_transformer_contract_shapes(self):
        p = transformer_program(seq=128, d_model=256, d_ff=512)
        ins = program_input_shapes(p)
        assert ins[0] == [128, 256]
        assert ins[1] == [256, 768]
        assert len(ins) == 7
        assert program_output_shapes(p) == [[128, 256]]

    def test_unknown_type_rejected(self):
        with pytest.raises(ValueError):
            program_input_shapes({"type": "conv2d"})


class TestArtifactWriter:
    def test_writes_program_and_manifest(self, tmp_path):
        w = ArtifactWriter(str(tmp_path))
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        w.lower("t0", fn, shapes, kind="baseline",
                program=gemm_program(32, 32, 32), extra={"m": 32})
        w.finish()
        prog = json.loads((tmp_path / "t0.tprog.json").read_text())
        assert prog["format"] == TPROG_FORMAT
        assert prog["name"] == "t0"
        assert prog["program"]["type"] == "gemm"
        assert prog["program"]["dtype_in"] == "f16"
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["version"] == 1
        e = manifest["artifacts"][0]
        assert e["name"] == "t0"
        assert e["file"] == "t0.tprog.json"
        assert e["kind"] == "baseline"
        assert e["m"] == 32
        assert e["inputs"][0] == {"shape": [32, 32], "dtype": "f32"}
        assert e["outputs"][0] == {"shape": [32, 32], "dtype": "f32"}

    def test_schedule_embedded_for_generated(self, tmp_path):
        from compile.kernels import generate_matmul_with_schedule

        w = ArtifactWriter(str(tmp_path))
        cfg = PipelineConfig(m=64, n=64, k=64, tile_tb=(32, 32, 32),
                             tile_warp=(16, 16, 16))
        kernel, sched = generate_matmul_with_schedule(cfg)
        fn = as_f32_io(lambda a, b, c: (kernel(a, b, c),))
        shapes = [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 3
        w.lower(sched.name, fn, shapes, kind="generated",
                program=gemm_program(64, 64, 64),
                schedule=sched.to_json_dict())
        w.finish()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        s = manifest["artifacts"][0]["schedule"]
        assert s["tile_tb"] == [32, 32, 32]
        assert s["opt_level"] == 7
        assert s["grid"] == [2, 2]

    def test_program_graph_mismatch_rejected(self, tmp_path):
        # A descriptor whose contract disagrees with the traced graph
        # must fail at write time, not at Rust load time.
        w = ArtifactWriter(str(tmp_path))
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        with pytest.raises(ValueError, match="disagree"):
            w.lower("t0", fn, shapes, kind="baseline",
                    program=gemm_program(64, 64, 64))

    def test_duplicate_names_rejected_before_overwrite(self, tmp_path):
        # PR 1 quirk: ablation level 7 and the identically-configured
        # generated kernel share a variant name.  A second lower() under
        # the same name must fail up front — before it clobbers the
        # first artifact's descriptor file — so the manifest can never
        # carry two entries shadowing each other.
        w = ArtifactWriter(str(tmp_path))
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        w.lower("t0", fn, shapes, kind="generated",
                program=gemm_program(32, 32, 32))
        before = (tmp_path / "t0.tprog.json").read_text()
        with pytest.raises(ValueError, match="duplicate artifact name"):
            w.lower("t0", fn, shapes, kind="ablation",
                    program=gemm_program(32, 32, 32))
        assert (tmp_path / "t0.tprog.json").read_text() == before
        assert len(w.entries) == 1

    def test_ablation_suffix_disambiguates_full_opt_level(self, tmp_path):
        # The build-time fix for the collision above: the ablation
        # ladder suffixes every rung, so level 7 no longer reuses the
        # fig2 variant name even though the configs are identical.
        from compile.kernels import generate_matmul_with_schedule

        w = ArtifactWriter(str(tmp_path))
        cfg = PipelineConfig(m=64, n=64, k=64, tile_tb=(32, 32, 32),
                             tile_warp=(16, 16, 16))
        full = PipelineConfig.opt_level(
            7, m=64, n=64, k=64, tile_tb=(32, 32, 32),
            tile_warp=(16, 16, 16))
        assert cfg.variant_name() == full.variant_name()  # the collision
        for config, suffix, kind in [(cfg, "", "generated"),
                                     (full, "__abl7", "ablation")]:
            kernel, sched = generate_matmul_with_schedule(config)
            fn = as_f32_io(lambda a, b, c, kernel=kernel: (kernel(a, b, c),))
            shapes = [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 3
            w.lower(sched.name + suffix, fn, shapes, kind=kind,
                    program=gemm_program(64, 64, 64),
                    schedule=sched.to_json_dict())
        w.finish()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        names = [e["name"] for e in manifest["artifacts"]]
        assert len(names) == len(set(names)) == 2
        assert names[1] == names[0] + "__abl7"

    def test_hlo_side_channel(self, tmp_path):
        w = ArtifactWriter(str(tmp_path), emit_hlo=True)
        fn = as_f32_io(matmul_baseline(32, 32, 32))
        shapes = [jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 3
        w.lower("t0", fn, shapes, kind="baseline",
                program=gemm_program(32, 32, 32))
        w.finish()
        assert (tmp_path / "t0.hlo.txt").read_text().startswith("HloModule")
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["artifacts"][0]["hlo_file"] == "t0.hlo.txt"


class TestTileCandidates:
    def test_small_sizes_get_small_tiles_only(self):
        assert tile_candidates(256) == [((64, 64, 64), (32, 32, 32))]

    def test_large_sizes_include_paper_tile(self):
        cands = tile_candidates(1024)
        assert ((128, 128, 64), (64, 32, 32)) in cands


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    def _manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")
        return json.load(open(path))

    def test_all_files_exist(self):
        m = self._manifest()
        base = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        for e in m["artifacts"]:
            assert os.path.exists(os.path.join(base, e["file"])), e["name"]

    def test_kinds_cover_every_experiment(self):
        kinds = {e["kind"] for e in self._manifest()["artifacts"]}
        assert {"generated", "baseline", "ablation", "fused", "unfused",
                "hand", "transformer"} <= kinds

    def test_ablation_ladder_complete(self):
        abl = [e for e in self._manifest()["artifacts"] if e["kind"] == "ablation"]
        levels = sorted(e["schedule"]["opt_level"] for e in abl)
        assert levels == list(range(8))

    def test_artifact_names_unique(self):
        names = [e["name"] for e in self._manifest()["artifacts"]]
        dupes = {n for n in names if names.count(n) > 1}
        assert not dupes, f"colliding artifact names: {sorted(dupes)}"

    def test_io_all_f32(self):
        for e in self._manifest()["artifacts"]:
            for s in e["inputs"] + e["outputs"]:
                assert s["dtype"] == "f32", e["name"]

    def test_every_program_parses_and_matches_manifest(self):
        base = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        for e in self._manifest()["artifacts"]:
            prog = json.load(open(os.path.join(base, e["file"])))
            assert prog["format"] == TPROG_FORMAT, e["name"]
            assert prog["name"] == e["name"]
            want_in = program_input_shapes(prog["program"])
            got_in = [s["shape"] for s in e["inputs"]]
            assert got_in == want_in, e["name"]
