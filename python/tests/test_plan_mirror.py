"""Python mirror of the Rust execution-plan compiler's cost model.

``rust/src/plan/mod.rs`` lowers a GemmKey through six passes (tile
selection, packing, thread partitioning, epilogue attachment, prepack,
ISA lowering) under a deterministic ``PlanEnv``.  The golden plan files
in ``rust/tests/golden/`` pin its decisions for the paper's Table 1
shape family under ``PlanEnv::pinned()`` (4 hw threads, pool of 1,
256 KiB L2, 8 MiB L3, ISA pinned to avx2 — no host probe).  This mirror
recomputes every decision from scratch in Python, so a cost-model change
is caught on the Python side of CI even before the Rust golden test runs
— and, in toolchain-less development containers, it is the only
executable check of the pass pipeline.

Mirrored from rust/src/plan/mod.rs (`compile`) and
rust/src/autotune/mod.rs (`cpu_blockings`); keep the two in sync.
Field-by-field schema reference: docs/PLAN_SCHEMA.md.
"""

import json
import pathlib

GOLDEN_DIR = (
    pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "golden"
)

# PlanEnv::pinned()
L2_BYTES = 256 * 1024
L3_BYTES = 8 * 1024 * 1024
HW_THREADS = 4
POOL_THREADS = 1
PINNED_ISA = "avx2"  # IsaPref::Fixed(Isa::Avx2Fma)

# runtime/kernel.rs constants
MR = 4
MIN_FLOPS_PER_THREAD = 4e6


def cpu_blockings():
    """autotune::cpu_blockings(), in the same enumeration order."""
    return [
        (mc, kc, nc)
        for mc in (64, 128, 256)
        for kc in (128, 256, 512)
        for nc in (256, 1024)
    ]


def ceil_div(x, d):
    return 0 if d == 0 else -(-x // d)


def traffic_elems(m, n, k, blocking):
    """plan::traffic_elems — modeled element traffic of one blocked sweep."""
    mc, kc, nc = blocking
    a = m * k * ceil_div(n, nc)
    b = k * n
    c = 2 * m * n * ceil_div(k, kc)
    return a + b + c


def compile_plan(m, n, k, epilogue, force="auto"):
    """plan::compile under PlanEnv::pinned().

    ``force`` mirrors the plan override: ``"auto"`` runs the scalar
    pipeline (bit_exact), ``"simd"`` opts into the pass-6 nanokernel
    lowering under the pinned ISA (fma_relaxed).  Returns the fields the
    golden files pin: the lowered kernel name, fuse_epilogue, prepack,
    and the numerics class.
    """
    # Pass 1 — tile selection: feasible candidates ranked by traffic,
    # ties broken toward the smallest packed panels then the largest
    # mc/kc/nc (a strict total order; Rust uses min_by_key on the same
    # tuple with Reverse() where we negate).
    candidates = cpu_blockings()
    feasible = [
        b
        for b in candidates
        if b[0] * b[1] * 4 <= L2_BYTES // 2 and b[1] * b[2] * 4 <= L3_BYTES // 2
    ]
    pool = feasible if feasible else candidates

    def score(b):
        mc, kc, nc = b
        return (
            traffic_elems(m, n, k, b),
            (mc * kc + kc * nc) * 4,
            -mc,
            -kc,
            -nc,
        )

    best = min(pool, key=score)

    # Pass 2 — packing decision: operand footprint within half of L2
    # lowers to the direct (naive-loop) kernel.
    footprint = 4 * (m * k + k * n + m * n)
    packed = footprint > L2_BYTES // 2

    # Pass 3 — thread partitioning.
    if not packed or POOL_THREADS > 1:
        bands = 1
    else:
        by_work = int(2.0 * m * n * k / MIN_FLOPS_PER_THREAD)  # Rust `as usize`
        bands = max(1, min(HW_THREADS, max(by_work, 1), ceil_div(m, MR)))

    # Pass 4 — epilogue attachment.
    fuse_epilogue = epilogue != "none"

    # Scalar lowering (plan::compile's auto kernel).
    if not packed:
        kernel = "naive"
    elif bands > 1:
        kernel = f"threaded:{best[0]},{best[1]},{best[2]},{bands}"
    else:
        kernel = f"tiled:{best[0]},{best[1]},{best[2]}"

    # Pass 6 — ISA lowering (computed before pass 5 in Rust, same here:
    # the prepack decision must see the final kernel).  The auto pipeline
    # stays scalar/bit_exact; a simd override lowers to the nanokernel —
    # even for problems the scalar pipeline would run naive — with the
    # pass-1 blocking and pass-3 band count, and flips the class.
    if force == "simd":
        kernel = f"simd:{PINNED_ISA}:{best[0]},{best[1]},{best[2]},{bands}"
        numerics = "fma_relaxed"
    else:
        assert force == "auto", f"unknown force {force!r}"
        numerics = "bit_exact"

    # Pass 5 — prepack: panels are worth materializing at bind time
    # exactly when the lowered kernel packs B per call.
    prepack = kernel != "naive"

    return {
        "kernel": kernel,
        "fuse_epilogue": fuse_epilogue,
        "prepack": prepack,
        "numerics": numerics,
    }


def test_golden_plans_match_the_mirror():
    goldens = sorted(GOLDEN_DIR.glob("plan_*.json"))
    assert len(goldens) >= 5, f"golden plan files missing under {GOLDEN_DIR}"
    for path in goldens:
        g = json.loads(path.read_text())
        got = compile_plan(g["m"], g["n"], g["k"], g["epilogue"],
                           force=g.get("force", "auto"))
        for field in ("kernel", "fuse_epilogue", "prepack", "numerics"):
            assert got[field] == g[field], (
                f"{path.name}: mirror computed {field}={got[field]!r}, "
                f"golden pins {g[field]!r} — cost model and goldens drifted"
            )


def test_known_decision_points():
    # Cache-resident problems lower to the direct kernel, no prepack.
    assert compile_plan(64, 64, 64, "none") == {
        "kernel": "naive",
        "fuse_epilogue": False,
        "prepack": False,
        "numerics": "bit_exact",
    }
    # 512^3: min traffic at kc=512, nc=1024; only mc=64 keeps the A panel
    # within L2/2; enough flops for all four pinned hw threads.
    assert compile_plan(512, 512, 512, "none")["kernel"] == "threaded:64,512,1024,4"
    # 256^3: kc=256 reaches ceil(k/kc)=1 with the smaller panels.
    assert compile_plan(256, 256, 256, "none")["kernel"] == "threaded:64,256,256,4"
    # Epilogue keys fuse; packing/prepack decisions are epilogue-blind.
    plan = compile_plan(512, 512, 512, "bias_relu")
    assert plan["fuse_epilogue"] and plan["prepack"]
    # Skinny-m problems cap the band count at ceil(m/MR).
    assert compile_plan(8, 2048, 2048, "none")["kernel"].startswith("threaded:")
    band = int(compile_plan(8, 2048, 2048, "none")["kernel"].rsplit(",", 1)[1])
    assert band == 2, f"ceil(8/4) = 2 bands, mirror says {band}"


def test_simd_override_decision_points():
    # The simd opt-in keeps the pass-1/pass-3 decisions and swaps the
    # lowering: same blocking and band count, fma_relaxed class.
    plan = compile_plan(512, 512, 512, "none", force="simd")
    assert plan["kernel"] == "simd:avx2:64,512,1024,4"
    assert plan["numerics"] == "fma_relaxed"
    assert plan["prepack"], "nanokernels consume packed panels"
    # Even a cache-resident problem lowers to the nanokernel when the
    # operator explicitly asked for SIMD (and then prepacks).
    small = compile_plan(64, 64, 64, "none", force="simd")
    assert small["kernel"].startswith("simd:avx2:")
    assert small["prepack"]
    # The auto pipeline never lowers to SIMD: bit_exact is the default.
    assert compile_plan(512, 512, 512, "none")["numerics"] == "bit_exact"


def test_every_prepack_decision_follows_the_kernel():
    # The prepack pass is a pure function of the lowered kernel: panels
    # exist exactly when the kernel would pack B per call.
    for m, n, k in [(16, 16, 16), (64, 64, 64), (96, 96, 96), (128, 128, 128),
                    (256, 256, 256), (512, 512, 512), (1024, 768, 512)]:
        for force in ("auto", "simd"):
            plan = compile_plan(m, n, k, "none", force=force)
            assert plan["prepack"] == (plan["kernel"] != "naive"), plan
