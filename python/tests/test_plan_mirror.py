"""Python mirror of the Rust execution-plan compiler's cost model.

``rust/src/plan/mod.rs`` lowers a GemmKey through six passes (tile
selection, packing, thread partitioning, epilogue attachment, prepack,
ISA lowering) under a deterministic ``PlanEnv``.  The golden plan files
in ``rust/tests/golden/`` pin its decisions for the paper's Table 1
shape family under ``PlanEnv::pinned()`` (4 hw threads, pool of 1,
256 KiB L2, 8 MiB L3, ISA pinned to avx2 — no host probe).  This mirror
recomputes every decision from scratch in Python, so a cost-model change
is caught on the Python side of CI even before the Rust golden test runs
— and, in toolchain-less development containers, it is the only
executable check of the pass pipeline.

Mirrored from rust/src/plan/mod.rs (`compile`) and
rust/src/autotune/mod.rs (`cpu_blockings`); keep the two in sync.
Field-by-field schema reference: docs/PLAN_SCHEMA.md.
"""

import json
import pathlib

GOLDEN_DIR = (
    pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "golden"
)

# PlanEnv::pinned()
L2_BYTES = 256 * 1024
L3_BYTES = 8 * 1024 * 1024
HW_THREADS = 4
POOL_THREADS = 1
PINNED_ISA = "avx2"  # IsaPref::Fixed(Isa::Avx2Fma)

# runtime/kernel.rs constants
MR = 4
MIN_FLOPS_PER_THREAD = 4e6


def cpu_blockings():
    """autotune::cpu_blockings(), in the same enumeration order."""
    return [
        (mc, kc, nc)
        for mc in (64, 128, 256)
        for kc in (128, 256, 512)
        for nc in (256, 1024)
    ]


def ceil_div(x, d):
    return 0 if d == 0 else -(-x // d)


def traffic_elems(m, n, k, blocking):
    """plan::traffic_elems — modeled element traffic of one blocked sweep."""
    mc, kc, nc = blocking
    a = m * k * ceil_div(n, nc)
    b = k * n
    c = 2 * m * n * ceil_div(k, kc)
    return a + b + c


def compile_plan(m, n, k, epilogue, force="auto", isa=PINNED_ISA):
    """plan::compile under PlanEnv::pinned().

    ``force`` mirrors the plan override: ``"auto"`` runs the scalar
    pipeline (bit_exact), ``"simd"`` opts into the pass-6 nanokernel
    lowering under ``isa`` (fma_relaxed) — the pinned env fixes avx2,
    but pass 6 pins exactly what IsaPref names, including ISAs the
    compile host lacks (dispatch degrades at execution, not compile).
    Returns the fields the golden files pin: the lowered kernel name,
    fuse_epilogue, prepack, and the numerics class.
    """
    # Pass 1 — tile selection: feasible candidates ranked by traffic,
    # ties broken toward the smallest packed panels then the largest
    # mc/kc/nc (a strict total order; Rust uses min_by_key on the same
    # tuple with Reverse() where we negate).
    candidates = cpu_blockings()
    feasible = [
        b
        for b in candidates
        if b[0] * b[1] * 4 <= L2_BYTES // 2 and b[1] * b[2] * 4 <= L3_BYTES // 2
    ]
    pool = feasible if feasible else candidates

    def score(b):
        mc, kc, nc = b
        return (
            traffic_elems(m, n, k, b),
            (mc * kc + kc * nc) * 4,
            -mc,
            -kc,
            -nc,
        )

    best = min(pool, key=score)

    # Pass 2 — packing decision: operand footprint within half of L2
    # lowers to the direct (naive-loop) kernel.
    footprint = 4 * (m * k + k * n + m * n)
    packed = footprint > L2_BYTES // 2

    # Pass 3 — thread partitioning.
    if not packed or POOL_THREADS > 1:
        bands = 1
    else:
        by_work = int(2.0 * m * n * k / MIN_FLOPS_PER_THREAD)  # Rust `as usize`
        bands = max(1, min(HW_THREADS, max(by_work, 1), ceil_div(m, MR)))

    # Pass 4 — epilogue attachment.
    fuse_epilogue = epilogue != "none"

    # Scalar lowering (plan::compile's auto kernel).
    if not packed:
        kernel = "naive"
    elif bands > 1:
        kernel = f"threaded:{best[0]},{best[1]},{best[2]},{bands}"
    else:
        kernel = f"tiled:{best[0]},{best[1]},{best[2]}"

    # Pass 6 — ISA lowering (computed before pass 5 in Rust, same here:
    # the prepack decision must see the final kernel).  The auto pipeline
    # stays scalar/bit_exact; a simd override lowers to the nanokernel —
    # even for problems the scalar pipeline would run naive — with the
    # pass-1 blocking and pass-3 band count, and flips the class.
    if force == "simd":
        assert isa in ("avx512", "avx2", "neon", "portable"), isa
        kernel = f"simd:{isa}:{best[0]},{best[1]},{best[2]},{bands}"
        numerics = "fma_relaxed"
    else:
        assert force == "auto", f"unknown force {force!r}"
        numerics = "bit_exact"

    # Pass 5 — prepack: panels are worth materializing at bind time
    # exactly when the lowered kernel packs B per call.
    prepack = kernel != "naive"

    return {
        "kernel": kernel,
        "fuse_epilogue": fuse_epilogue,
        "prepack": prepack,
        "numerics": numerics,
    }


def test_golden_plans_match_the_mirror():
    goldens = sorted(GOLDEN_DIR.glob("plan_*.json"))
    assert len(goldens) >= 5, f"golden plan files missing under {GOLDEN_DIR}"
    for path in goldens:
        g = json.loads(path.read_text())
        got = compile_plan(g["m"], g["n"], g["k"], g["epilogue"],
                           force=g.get("force", "auto"))
        for field in ("kernel", "fuse_epilogue", "prepack", "numerics"):
            assert got[field] == g[field], (
                f"{path.name}: mirror computed {field}={got[field]!r}, "
                f"golden pins {g[field]!r} — cost model and goldens drifted"
            )


def test_known_decision_points():
    # Cache-resident problems lower to the direct kernel, no prepack.
    assert compile_plan(64, 64, 64, "none") == {
        "kernel": "naive",
        "fuse_epilogue": False,
        "prepack": False,
        "numerics": "bit_exact",
    }
    # 512^3: min traffic at kc=512, nc=1024; only mc=64 keeps the A panel
    # within L2/2; enough flops for all four pinned hw threads.
    assert compile_plan(512, 512, 512, "none")["kernel"] == "threaded:64,512,1024,4"
    # 256^3: kc=256 reaches ceil(k/kc)=1 with the smaller panels.
    assert compile_plan(256, 256, 256, "none")["kernel"] == "threaded:64,256,256,4"
    # Epilogue keys fuse; packing/prepack decisions are epilogue-blind.
    plan = compile_plan(512, 512, 512, "bias_relu")
    assert plan["fuse_epilogue"] and plan["prepack"]
    # Skinny-m problems cap the band count at ceil(m/MR).
    assert compile_plan(8, 2048, 2048, "none")["kernel"].startswith("threaded:")
    band = int(compile_plan(8, 2048, 2048, "none")["kernel"].rsplit(",", 1)[1])
    assert band == 2, f"ceil(8/4) = 2 bands, mirror says {band}"


def test_simd_override_decision_points():
    # The simd opt-in keeps the pass-1/pass-3 decisions and swaps the
    # lowering: same blocking and band count, fma_relaxed class.
    plan = compile_plan(512, 512, 512, "none", force="simd")
    assert plan["kernel"] == "simd:avx2:64,512,1024,4"
    assert plan["numerics"] == "fma_relaxed"
    assert plan["prepack"], "nanokernels consume packed panels"
    # Even a cache-resident problem lowers to the nanokernel when the
    # operator explicitly asked for SIMD (and then prepacks).
    small = compile_plan(64, 64, 64, "none", force="simd")
    assert small["kernel"].startswith("simd:avx2:")
    assert small["prepack"]
    # The auto pipeline never lowers to SIMD: bit_exact is the default.
    assert compile_plan(512, 512, 512, "none")["numerics"] == "bit_exact"


def test_simd_candidates_cover_every_nanokernel_isa():
    # Pass 6 pins exactly what IsaPref names — the shadow tuner compiles
    # its candidate for the *detected* host ISA, so every nanokernel body
    # must lower with the same pass-1/pass-3 decisions.  The wide ISAs
    # (avx512, neon) are legitimate compile targets even on hosts that
    # lack them: plans are portable, dispatch degrades at execution.
    for isa in ("avx512", "avx2", "neon", "portable"):
        plan = compile_plan(512, 512, 512, "none", force="simd", isa=isa)
        assert plan["kernel"] == f"simd:{isa}:64,512,1024,4"
        assert plan["numerics"] == "fma_relaxed"
        assert plan["prepack"]


def test_every_prepack_decision_follows_the_kernel():
    # The prepack pass is a pure function of the lowered kernel: panels
    # exist exactly when the kernel would pack B per call.
    for m, n, k in [(16, 16, 16), (64, 64, 64), (96, 96, 96), (128, 128, 128),
                    (256, 256, 256), (512, 512, 512), (1024, 768, 512)]:
        for force in ("auto", "simd"):
            plan = compile_plan(m, n, k, "none", force=force)
            assert plan["prepack"] == (plan["kernel"] != "naive"), plan


# ---------------------------------------------------------------------------
# Graph-level ProgramPlan mirror (rust/src/plan/program.rs).
#
# The per-GEMM mirror above replays ``plan_*.json``; the transformer golden
# uses the ``program_plan_*`` prefix precisely so that glob skips it.  Here
# we recompute the four graph passes — op-graph extraction, cast hoisting,
# lifetime-based buffer reuse, chained-GEMM pipelining — from scratch and
# diff them against ``program_plan_8x16x32x4_f16.json``.  Per-op lowering
# reuses ``compile_plan`` (the same 6-pass pipeline the Rust compiler calls
# per op, with epilogue "none" and f32 accumulate).


def transformer_ops(seq, d_model, d_ff, n_heads, dtype_in):
    """Pass 1 — op-graph extraction, in compile order.

    Returns (name, count, m, n, k, op_dtype_in); attention internals run
    on post-cast f32 activations regardless of the program dtype.
    """
    d_head = d_model // n_heads
    return [
        ("qkv", 1, seq, 3 * d_model, d_model, dtype_in),
        ("scores", n_heads, seq, seq, d_head, "f32"),
        ("ctx", n_heads, seq, d_head, seq, "f32"),
        ("attn_out", 1, seq, d_model, d_model, dtype_in),
        ("ffn_up", 1, seq, d_ff, d_model, dtype_in),
        ("ffn_dn", 1, seq, d_model, d_ff, dtype_in),
    ]


def cast_hoists(dtype_in):
    """Pass 2 — one shared x cast feeds q/k/v when activations cast."""
    if dtype_in == "f32":
        return []
    return [{"operand": "x", "users": ["q", "k", "v"], "casts_saved": 2}]


def transformer_buffers(seq, d_model, d_ff, n_heads, cast):
    """The executor's intermediates as (name, elems, birth, death) over
    the 12-step linear schedule, in birth order (program.rs
    ``transformer_buffers``; cast buffers exist only for non-f32)."""
    d_head = d_model // n_heads
    bufs = []
    if cast:
        bufs.append(("x_cast", seq * d_model, 0, 1))
    bufs += [
        ("qkv", seq * 3 * d_model, 1, 2),
        ("q_head", seq * d_head, 2, 2),
        ("kt_head", d_head * seq, 2, 2),
        ("v_head", seq * d_head, 2, 2),
        ("scores", seq * seq, 2, 2),
        ("ctx_head", seq * d_head, 2, 2),
        ("denom", seq, 2, 2),
        ("ctx", seq * d_model, 2, 4),
    ]
    if cast:
        bufs.append(("ctx_cast", seq * d_model, 3, 4))
    bufs += [
        ("attn_out", seq * d_model, 4, 5),
        ("h_res", seq * d_model, 5, 11),
        ("hn", seq * d_model, 6, 8),
    ]
    if cast:
        bufs.append(("hn_cast", seq * d_model, 7, 8))
    bufs.append(("up", seq * d_ff, 8, 10))
    if cast:
        bufs.append(("up_cast", seq * d_ff, 9, 10))
    return bufs


def arena_assign(bufs):
    """Pass 3 — first-fit interval packing: reuse the lowest-indexed slot
    whose last occupant died strictly before this buffer's birth."""
    slots = []  # [last_death, elems, [names]]
    for name, elems, birth, death in bufs:
        for slot in slots:
            if slot[0] < birth:
                slot[0] = death
                slot[1] = max(slot[1], elems)
                slot[2].append(name)
                break
        else:
            slots.append([death, elems, [name]])
    return [
        {"slot": i, "elems": elems, "buffers": names}
        for i, (_, elems, names) in enumerate(slots)
    ]


def pipeline_edges():
    """Pass 4 — conservative default: every chained-GEMM edge
    materializes (streaming is opt-in and carries fma_relaxed)."""
    return [
        {"producer": "qkv", "consumer": "scores", "mode": "materialize"},
        {"producer": "scores", "consumer": "ctx", "mode": "materialize"},
        {"producer": "ctx", "consumer": "attn_out", "mode": "materialize"},
        {"producer": "ffn_up", "consumer": "ffn_dn", "mode": "materialize"},
    ]


def compile_program_plan(seq, d_model, d_ff, n_heads, dtype_in):
    """plan::program::compile_program under PlanEnv::pinned(), reduced to
    the decisions the golden pins."""
    ops = []
    for name, count, m, n, k, op_dtype in transformer_ops(
        seq, d_model, d_ff, n_heads, dtype_in
    ):
        lowered = compile_plan(m, n, k, "none")
        ops.append(
            {
                "name": name,
                "count": count,
                "m": m,
                "n": n,
                "k": k,
                "dtype_in": op_dtype,
                "kernel": lowered["kernel"],
                "numerics": lowered["numerics"],
            }
        )
    numerics = (
        "fma_relaxed"
        if any(o["numerics"] == "fma_relaxed" for o in ops)
        else "bit_exact"
    )
    return {
        "ops": ops,
        "cast_hoists": cast_hoists(dtype_in),
        "arena": arena_assign(
            transformer_buffers(seq, d_model, d_ff, n_heads, dtype_in != "f32")
        ),
        "pipeline": pipeline_edges(),
        "numerics": numerics,
    }


def test_golden_program_plan_matches_the_graph_pass_mirror():
    path = GOLDEN_DIR / "program_plan_8x16x32x4_f16.json"
    g = json.loads(path.read_text())
    got = compile_program_plan(
        g["seq"], g["d_model"], g["d_ff"], g["n_heads"], g["dtype_in"]
    )
    assert got["numerics"] == g["numerics"], (
        f"mirror derives numerics {got['numerics']!r}, golden pins "
        f"{g['numerics']!r}"
    )
    assert len(got["ops"]) == len(g["ops"])
    for mine, theirs in zip(got["ops"], g["ops"]):
        assert mine["name"] == theirs["name"]
        assert mine["count"] == theirs["count"], mine["name"]
        plan = theirs["plan"]
        for field in ("m", "n", "k", "dtype_in", "kernel", "numerics"):
            assert mine[field] == plan[field], (
                f"op {mine['name']}: mirror computed {field}={mine[field]!r}, "
                f"golden pins {plan[field]!r} — graph passes and golden drifted"
            )
    assert got["cast_hoists"] == g["cast_hoists"]
    assert got["arena"] == g["arena"], (
        "first-fit arena assignment drifted from the golden"
    )
    assert got["pipeline"] == g["pipeline"]


def test_program_plan_decision_points():
    # f32 activations: no cast buffers, no hoist — fewer buffers land in
    # the arena (the slot count happens to stay 8; the peak-liveness head
    # loop sets it in both modes).
    f16 = compile_program_plan(8, 16, 32, 4, "f16")
    f32 = compile_program_plan(8, 16, 32, 4, "f32")
    assert f32["cast_hoists"] == []
    placed = lambda plan: sum(len(s["buffers"]) for s in plan["arena"])
    assert placed(f32) < placed(f16)
    # Reuse is real: strictly fewer slots than buffers in both modes,
    # and every buffer is placed exactly once.
    for plan, cast in ((f16, True), (f32, False)):
        n_bufs = len(transformer_buffers(8, 16, 32, 4, cast))
        assert placed(plan) == n_bufs
        assert len(plan["arena"]) < n_bufs
    # Every default edge materializes — streaming never appears
    # without the opt-in (which this mirror deliberately has no knob
    # for: the conservative setting is the only bit-exact one).
    assert all(e["mode"] == "materialize" for e in f16["pipeline"])
    # Tiny ops all lower to the direct kernel under the pinned caches,
    # so the whole program stays bit_exact.
    assert all(o["kernel"] == "naive" for o in f16["ops"])
    assert f16["numerics"] == "bit_exact"


# ---------------------------------------------------------------------------
# Plan-DB mirror (rust/src/coordinator/shadow.rs, mlir-gemm-plandb-v1).
#
# The shadow tuner persists each promotion decision keyed by the GEMM
# identity plus a hardware fingerprint.  The key is *derived* from the
# record's fields and re-checked on load (a hand-edited record cannot
# silently mislabel a plan), so the derivation itself is part of the
# interchange format — mirror it here and pin it against the golden.


def plandb_key(m, n, k, dtype_in, dtype_acc, epilogue, threads, isa):
    """shadow::db_key — everything left of ``@`` is the GEMM key,
    everything right is the hardware fingerprint the measurement is
    valid for (pool width + resolved nanokernel ISA)."""
    return f"{m}x{n}x{k}/{dtype_in}->{dtype_acc}+{epilogue}@t{threads}/{isa}"


def test_golden_plandb_record_key_rederives():
    path = GOLDEN_DIR / "plandb_v1.json"
    g = json.loads(path.read_text())
    assert g["format"] == "mlir-gemm-plandb-v1"
    assert len(g["records"]) >= 1
    for rec in g["records"]:
        derived = plandb_key(
            rec["m"], rec["n"], rec["k"],
            rec["dtype_in"], rec["dtype_acc"], rec["epilogue"],
            rec["threads"], rec["isa"],
        )
        assert rec["key"] == derived, (
            f"stored key {rec['key']!r} does not re-derive from the "
            f"record fields ({derived!r}) — the db_key grammar drifted"
        )
        # The embedded plan is a full mlir-gemm-plan-v1 document for the
        # record's own shape: the same cross-contamination guard the
        # Rust loader enforces via matches_gemm.
        plan = rec["plan"]
        assert plan["format"] == "mlir-gemm-plan-v1"
        for field in ("m", "n", "k", "dtype_in", "dtype_acc", "epilogue"):
            assert plan[field] == rec[field], field
        # A promoted plan is always a nanokernel lowering: that is the
        # only candidate the shadow tuner ever races — and the mirror's
        # own pass pipeline agrees on the class such a kernel carries.
        assert plan["kernel"].startswith("simd:")
        assert plan["numerics"] == "fma_relaxed"
        assert rec["isa"] in ("avx512", "avx2", "neon", "portable")
        # Fingerprint sanity: measured throughput is recorded for both
        # sides and the promoted side won (by at least the margin the
        # hysteresis demands — don't over-pin the exact ratio here).
        assert rec["candidate_gflops"] > rec["incumbent_gflops"]
        assert rec["samples"] >= 1
