"""L1 kernels: the generated Pallas matmul family and its oracles."""

from .emitter import EmitError, emit_kernel
from .matmul_pallas import (
    generate_matmul,
    generate_matmul_with_schedule,
    hand_optimized_matmul,
)
from .ref import (
    epilogue_ref,
    jdtype,
    matmul_bias_ref,
    matmul_bias_relu_ref,
    matmul_ref,
)

__all__ = [
    "EmitError",
    "emit_kernel",
    "generate_matmul",
    "generate_matmul_with_schedule",
    "hand_optimized_matmul",
    "epilogue_ref",
    "jdtype",
    "matmul_bias_ref",
    "matmul_bias_relu_ref",
    "matmul_ref",
]
