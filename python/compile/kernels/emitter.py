"""Schedule -> Pallas kernel emitter (the pipeline's TPU backend).

The tile-IR pipeline's final module is summarized by a ``Schedule``; this
emitter turns a Schedule into an executable Pallas kernel.  The mapping
from the paper's CUDA concepts to Pallas/TPU idiom (DESIGN.md
§Hardware-Adaptation):

* thread-block tile (tbm, tbn, tbk)  ->  grid cell + VMEM BlockSpecs;
* global->shared copy loops          ->  the HBM->VMEM pipeline BlockSpec
  describes (XLA issues the DMAs);
* warp tile / WMMA fragments         ->  unrolled 16x16x16 ``jnp.dot``
  fragments with ``preferred_element_type`` (MXU contraction);
* C hoisted into iter_args           ->  VMEM accumulator scratch written
  back once, at the last k grid step;
* software pipelining (§3.5/§3.10)   ->  "arbitrary" dimension semantics on
  the k grid axis (XLA double-buffers the tile stream).

Optimization levels and their structural effect here:

  0  naive        grid=(1,), rank-1 (CUDA-core-style) k-loop, no tiling
  1  +tiling      (i, j) grid, full-K panels streamed per tile
  2  +shared_mem  (i, j, k) grid: K tiled and staged through VMEM;
                  C read-modify-written every k step (not yet hoisted)
  3  +wmma        fragment jnp.dot MXU compute inside the k step
  4  +hoist       VMEM accumulator scratch, single C read + write-back
  5  +latency     k axis marked "arbitrary" (double-buffered stream)
  6  +padding     memory-system effect only: no structural change under
                  interpret mode; modeled by the Rust simulator
  7  +vectorize   likewise memory-system only (transaction width)

Pallas is always invoked with ``interpret=True``: real-TPU lowering emits
Mosaic custom-calls the CPU PJRT plugin cannot execute.  Numerical
correctness of every level is pytest-validated against ``ref.py``.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import jdtype

try:  # TPU scratch memory spaces work under interpret mode too
    from jax.experimental.pallas import tpu as pltpu

    _HAVE_PLTPU = True
except Exception:  # pragma: no cover - pltpu ships with jax, but be safe
    _HAVE_PLTPU = False


class EmitError(ValueError):
    pass


def _check(schedule) -> None:
    if schedule.m % schedule.tile_tb[0] or schedule.n % schedule.tile_tb[1] or (
        schedule.k % schedule.tile_tb[2]
    ):
        raise EmitError(
            f"problem {schedule.m}x{schedule.n}x{schedule.k} not a multiple "
            f"of tile {schedule.tile_tb}"
        )


def _epilogue(acc, bias, name: str):
    """Apply the fused epilogue on the final accumulator tile."""
    if name == "none":
        return acc
    out = acc + bias[...].astype(acc.dtype).reshape(1, -1)
    if name == "bias_relu":
        out = jnp.maximum(out, 0)
    return out


def _fragment_matmul(a_tile, b_tile, acc, schedule):
    """The warp/fragment compute of one (tbm, tbk) x (tbk, tbn) tile pair.

    The tile-IR models this as the fully unrolled (kkk, iii, jjj) grid of
    16x16x16 WMMA fragments (§3.4); on the MXU the whole tile contraction
    is one systolic pass, so the emitter coalesces the fragment grid into a
    single ``jnp.dot`` with a widened ``preferred_element_type`` — the same
    coalescing ptxas performs when it schedules the unrolled HMMA stream.
    Numerically identical (dot is evaluated fragment-wise in f32 on both
    paths); structurally this is also what makes the interpret-mode CPU
    artifacts executable at speed (L1 perf pass, EXPERIMENTS.md §Perf).
    """
    accd = jdtype(schedule.dtype_acc)
    return acc + jnp.dot(a_tile, b_tile, preferred_element_type=accd)


# ---------------------------------------------------------------------------
# Level 0: naive (no tiling) — rank-1 updates on CUDA-core-style compute.
# ---------------------------------------------------------------------------


def _emit_naive(schedule, bias: bool):
    accd = jdtype(schedule.dtype_acc)

    def kernel(*refs):
        if bias:
            a_ref, b_ref, c_ref, bias_ref, o_ref = refs
        else:
            a_ref, b_ref, c_ref, o_ref = refs
        a = a_ref[...].astype(accd)
        b = b_ref[...].astype(accd)

        def body(kk, acc):
            col = jax.lax.dynamic_slice_in_dim(a, kk, 1, axis=1)
            row = jax.lax.dynamic_slice_in_dim(b, kk, 1, axis=0)
            return acc + col * row

        acc = jax.lax.fori_loop(0, schedule.k, body, c_ref[...].astype(accd))
        o_ref[...] = _epilogue(
            acc, (refs[3] if bias else None), schedule.epilogue
        ).astype(accd)

    return kernel, (1,), None  # grid=(1,), whole-array blocks


# ---------------------------------------------------------------------------
# Level 1: tiled output, full-K panels (locality/parallelism, no staging).
# ---------------------------------------------------------------------------


def _emit_tiled(schedule, bias: bool):
    tbm, tbn, _ = schedule.tile_tb
    accd = jdtype(schedule.dtype_acc)

    def kernel(*refs):
        if bias:
            a_ref, b_ref, c_ref, bias_ref, o_ref = refs
        else:
            a_ref, b_ref, c_ref, o_ref = refs
        a = a_ref[...].astype(accd)
        b = b_ref[...].astype(accd)

        def body(kk, acc):
            col = jax.lax.dynamic_slice_in_dim(a, kk, 1, axis=1)
            row = jax.lax.dynamic_slice_in_dim(b, kk, 1, axis=0)
            return acc + col * row

        acc = jax.lax.fori_loop(0, schedule.k, body, c_ref[...].astype(accd))
        o_ref[...] = _epilogue(acc, (refs[3] if bias else None), schedule.epilogue).astype(
            accd
        )

    grid = (schedule.m // tbm, schedule.n // tbn)
    specs = dict(
        a=pl.BlockSpec((tbm, schedule.k), lambda i, j: (i, 0)),
        b=pl.BlockSpec((schedule.k, tbn), lambda i, j: (0, j)),
        c=pl.BlockSpec((tbm, tbn), lambda i, j: (i, j)),
        bias=pl.BlockSpec((1, tbn), lambda i, j: (0, j)),
        out=pl.BlockSpec((tbm, tbn), lambda i, j: (i, j)),
    )
    return kernel, grid, specs


# ---------------------------------------------------------------------------
# Levels 2+: k-tiled grid with VMEM staging.
# ---------------------------------------------------------------------------


def _emit_ktiled(schedule, bias: bool):
    """Shared(VMEM)-staged kernel; structure varies with opt level."""
    tbm, tbn, tbk = schedule.tile_tb
    accd = jdtype(schedule.dtype_acc)
    nk = schedule.k // tbk
    use_wmma = schedule.wmma
    hoisted = schedule.unroll_hoist

    def compute_tile(a_tile, b_tile, acc):
        if use_wmma:
            return _fragment_matmul(a_tile, b_tile, acc, schedule)

        def body(kk, acc_):
            col = jax.lax.dynamic_slice_in_dim(a_tile, kk, 1, axis=1).astype(accd)
            row = jax.lax.dynamic_slice_in_dim(b_tile, kk, 1, axis=0).astype(accd)
            return acc_ + col * row

        return jax.lax.fori_loop(0, tbk, body, acc)

    if hoisted:
        # Level 4+: accumulator lives in VMEM scratch across the k grid
        # dimension; C is read once (k == 0) and written once (k == nk-1) —
        # the iter_args structure of tile-IR Listing 3.
        def kernel(*refs):
            if bias:
                a_ref, b_ref, c_ref, bias_ref, o_ref, acc_ref = refs
            else:
                a_ref, b_ref, c_ref, o_ref, acc_ref = refs
            kidx = pl.program_id(2)

            @pl.when(kidx == 0)
            def _init():
                acc_ref[...] = c_ref[...].astype(accd)

            acc_ref[...] = compute_tile(a_ref[...], b_ref[...], acc_ref[...])

            @pl.when(kidx == nk - 1)
            def _writeback():
                o_ref[...] = _epilogue(
                    acc_ref[...], (refs[3] if bias else None), schedule.epilogue
                ).astype(accd)

        scratch = [pltpu.VMEM((tbm, tbn), accd)] if _HAVE_PLTPU else None
        if scratch is None:
            raise EmitError("hoisted kernels need pltpu VMEM scratch")
    else:
        # Levels 2-3: C tile is read-modify-written at every k step — the
        # pre-hoisting structure whose extra C traffic Figure 3 quantifies.
        def kernel(*refs):
            if bias:
                a_ref, b_ref, c_ref, bias_ref, o_ref = refs
            else:
                a_ref, b_ref, c_ref, o_ref = refs
            kidx = pl.program_id(2)

            @pl.when(kidx == 0)
            def _init():
                o_ref[...] = c_ref[...].astype(accd)

            o_ref[...] = compute_tile(a_ref[...], b_ref[...], o_ref[...])

            @pl.when(kidx == nk - 1)
            def _epi():
                o_ref[...] = _epilogue(
                    o_ref[...], (refs[3] if bias else None), schedule.epilogue
                ).astype(accd)

        scratch = None

    grid = (schedule.m // tbm, schedule.n // tbn, nk)
    specs = dict(
        a=pl.BlockSpec((tbm, tbk), lambda i, j, kk: (i, kk)),
        b=pl.BlockSpec((tbk, tbn), lambda i, j, kk: (kk, j)),
        c=pl.BlockSpec((tbm, tbn), lambda i, j, kk: (i, j)),
        bias=pl.BlockSpec((1, tbn), lambda i, j, kk: (0, j)),
        out=pl.BlockSpec((tbm, tbn), lambda i, j, kk: (i, j)),
    )
    return kernel, grid, specs, scratch


def emit_kernel(schedule) -> Callable:
    """Build the Pallas kernel for ``schedule``.

    Returns a function ``f(a, b, c)`` (or ``f(a, b, c, bias)`` for fused
    epilogues) producing the output matrix in the accumulator dtype.
    """
    _check(schedule)
    bias = schedule.epilogue != "none"
    accd = jdtype(schedule.dtype_acc)
    out_shape = jax.ShapeDtypeStruct((schedule.m, schedule.n), accd)
    scratch = None

    if not schedule.tiling:
        kernel, grid, specs = _emit_naive(schedule, bias)
    elif not schedule.shared_mem:
        kernel, grid, specs = _emit_tiled(schedule, bias)
    else:
        kernel, grid, specs, scratch = _emit_ktiled(schedule, bias)

    kwargs = {}
    if specs is not None:
        in_specs = [specs["a"], specs["b"], specs["c"]]
        if bias:
            in_specs.append(specs["bias"])
        kwargs.update(in_specs=in_specs, out_specs=specs["out"])
    if scratch is not None:
        kwargs.update(scratch_shapes=scratch)
    if schedule.latency_hiding and _HAVE_PLTPU and len(grid) == 3:
        # §3.5/§3.10's software pipelining: the k axis is a sequential
        # stream XLA may double-buffer.  Recorded for the real-TPU path;
        # harmless under interpret mode.
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            )
        except Exception:
            pass

    call = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        grid=grid,
        interpret=True,
        **kwargs,
    )

    ind = jdtype(schedule.dtype_in)

    if bias:

        def run(a, b, c, bias_vec):
            return call(
                a.astype(ind),
                b.astype(ind),
                c.astype(accd),
                bias_vec.reshape(1, -1).astype(accd),
            )

    else:

        def run(a, b, c):
            return call(a.astype(ind), b.astype(ind), c.astype(accd))

    run.schedule = schedule
    return run
