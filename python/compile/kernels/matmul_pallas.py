"""Kernel factory: tile-IR pipeline -> Schedule -> Pallas kernel.

``generate_matmul`` is the end-to-end code generator (the paper's whole
pipeline as one call).  ``hand_optimized_matmul`` is the Table 1 "assembly
level" comparator: a directly hand-written Pallas kernel that bypasses the
pipeline, representing what an expert would write against the lowest-level
API available.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..tileir import PipelineConfig, extract_schedule, run_pipeline
from .emitter import emit_kernel
from .ref import jdtype


def generate_matmul(config: PipelineConfig) -> Callable:
    """Run the full lowering pipeline for ``config`` and emit the kernel."""
    result = run_pipeline(config)
    schedule = extract_schedule(result.module, config)
    return emit_kernel(schedule)


def generate_matmul_with_schedule(config: PipelineConfig):
    """As ``generate_matmul`` but also returns the extracted Schedule."""
    result = run_pipeline(config)
    schedule = extract_schedule(result.module, config)
    return emit_kernel(schedule), schedule


def hand_optimized_matmul(
    m: int,
    n: int,
    k: int,
    dtype_in: str = "f16",
    dtype_acc: str = "f32",
    tile: Tuple[int, int, int] = (128, 128, 64),
) -> Callable:
    """Hand-written best-effort kernel (Table 1 "assembly" row analog).

    Written directly against Pallas with no pipeline involvement: single
    fused dot per tile (the largest contraction the MXU pipeline can
    consume), accumulator scratch, double-buffered k stream.
    """
    tbm, tbn, tbk = tile
    if m % tbm or n % tbn or k % tbk:
        raise ValueError(f"problem {m}x{n}x{k} not a multiple of tile {tile}")
    ind, accd = jdtype(dtype_in), jdtype(dtype_acc)
    nk = k // tbk

    def kernel(a_ref, b_ref, c_ref, o_ref, acc_ref):
        kidx = pl.program_id(2)

        @pl.when(kidx == 0)
        def _init():
            acc_ref[...] = c_ref[...].astype(accd)

        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=accd
        )

        @pl.when(kidx == nk - 1)
        def _writeback():
            o_ref[...] = acc_ref[...]

    call = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), accd),
        grid=(m // tbm, n // tbn, nk),
        in_specs=[
            pl.BlockSpec((tbm, tbk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tbk, tbn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tbm, tbn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((tbm, tbn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((tbm, tbn), accd)],
        interpret=True,
    )

    def run(a, b, c):
        return call(a.astype(ind), b.astype(ind), c.astype(accd))

    return run
