"""Pure-jnp correctness oracles for every kernel variant.

These are the ground truth the Pallas kernels (and transitively the HLO
artifacts the Rust runtime executes) are validated against.  They mirror
the paper's three precision modes:

* mixed precision — f16 inputs, f32 accumulate and output (§4.1);
* half precision  — f16 throughout (§4.2);
* f32 ("TF32" mode on tensor cores) — f32 throughout.

plus the fused epilogues used in the Table 1 operator-fusion comparison.
"""

from __future__ import annotations

import jax.numpy as jnp

_DTYPES = {"f16": jnp.float16, "bf16": jnp.bfloat16, "f32": jnp.float32}


def jdtype(name: str):
    """jnp dtype for a tile-IR dtype name."""
    return _DTYPES[name]


def matmul_ref(a, b, c, dtype_acc: str = "f32"):
    """C = A @ B + C with accumulation in ``dtype_acc``.

    ``preferred_element_type`` gives the MMA-style widened accumulate the
    tensor cores (and the MXU) implement for f16 inputs.
    """
    acc = jdtype(dtype_acc)
    d = jnp.matmul(a, b, preferred_element_type=acc)
    return (d + c.astype(acc)).astype(acc)


def matmul_bias_ref(a, b, c, bias, dtype_acc: str = "f32"):
    """Fused bias-add epilogue: (A @ B + C) + bias (row-broadcast)."""
    out = matmul_ref(a, b, c, dtype_acc)
    return (out + bias.reshape(1, -1).astype(out.dtype)).astype(out.dtype)


def matmul_bias_relu_ref(a, b, c, bias, dtype_acc: str = "f32"):
    """Fused bias + ReLU epilogue."""
    return jnp.maximum(matmul_bias_ref(a, b, c, bias, dtype_acc), 0)


def epilogue_ref(name: str):
    """Oracle for a named epilogue ('none' | 'bias' | 'bias_relu')."""
    if name == "none":
        return matmul_ref
    if name == "bias":
        return matmul_bias_ref
    if name == "bias_relu":
        return matmul_bias_relu_ref
    raise ValueError(f"unknown epilogue {name!r}")
