"""Pass manager for the paper's lowering pipeline (§3, Figure 1).

``PipelineConfig`` names the tunables (problem size, dtypes, tile sizes,
WMMA intrinsic shape, padding factor, vector width) and the optimization
toggles the ablation study (Figure 3) enables one at a time.  ``run_pipeline``
applies the passes in the paper's order, enforcing the dependency structure
between them, capturing a printed IR snapshot after every pass, and
(optionally) interpreter-validating each semantically complete stage
against the naive module.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from .builder import build_fused_matmul_bias_relu, build_naive_matmul
from .interp import run_matmul_module
from .ir import F16, F32, Module
from .printer import print_module
from . import passes as P


class PipelineError(ValueError):
    pass


# Ablation levels, in the cumulative order of Figure 3.
OPT_ORDER: Tuple[str, ...] = (
    "tiling",
    "shared_mem",
    "wmma",
    "unroll_hoist",  # permute + unroll + CSE + invariant hoisting
    "latency_hiding",
    "padding",
    "vectorize",
)


@dataclass(frozen=True)
class PipelineConfig:
    """Everything that determines one generated kernel variant."""

    m: int
    n: int
    k: int
    dtype_in: str = F16
    dtype_acc: str = F32
    tile_tb: Tuple[int, int, int] = (128, 128, 64)
    tile_warp: Tuple[int, int, int] = (64, 32, 32)
    wmma_mnk: Tuple[int, int, int] = (16, 16, 16)
    pad_factor: int = 8
    vec_width: int = 8
    epilogue: str = "none"  # none | bias | bias_relu
    # Optimization toggles (Figure 3 ablation).  ``opt_level(n)`` builds the
    # cumulative configurations.
    tiling: bool = True
    shared_mem: bool = True
    wmma: bool = True
    unroll_hoist: bool = True
    latency_hiding: bool = True
    padding: bool = True
    vectorize: bool = True

    # -- constructors --------------------------------------------------------
    @staticmethod
    def opt_level(level: int, **kw) -> "PipelineConfig":
        """Cumulative ablation config: level 0 = naive, 7 = fully optimized."""
        if not 0 <= level <= len(OPT_ORDER):
            raise PipelineError(f"opt level {level} out of range")
        toggles = {name: i < level for i, name in enumerate(OPT_ORDER)}
        return PipelineConfig(**{**toggles, **kw})

    # -- validation ----------------------------------------------------------
    def validate(self) -> None:
        tbm, tbn, tbk = self.tile_tb
        wm, wn, wk = self.tile_warp
        fm, fn, fk = self.wmma_mnk
        if self.m % tbm or self.n % tbn or self.k % tbk:
            raise PipelineError(
                f"problem {self.m}x{self.n}x{self.k} not a multiple of "
                f"thread-block tile {self.tile_tb}"
            )
        if tbm % wm or tbn % wn or tbk % wk:
            raise PipelineError(
                f"thread-block tile {self.tile_tb} not a multiple of warp "
                f"tile {self.tile_warp}"
            )
        if wm % fm or wn % fn or wk % fk:
            raise PipelineError(
                f"warp tile {self.tile_warp} not a multiple of WMMA {self.wmma_mnk}"
            )
        deps = [
            ("shared_mem", "tiling"),
            ("wmma", "tiling"),
            ("unroll_hoist", "wmma"),
            ("latency_hiding", "unroll_hoist"),
            ("latency_hiding", "shared_mem"),
            ("padding", "shared_mem"),
            ("vectorize", "shared_mem"),
        ]
        for opt, dep in deps:
            if getattr(self, opt) and not getattr(self, dep):
                raise PipelineError(f"optimization '{opt}' requires '{dep}'")
        if self.latency_hiding and self.k // tbk < 2:
            raise PipelineError("latency hiding needs at least two k-tiles")

    def level(self) -> int:
        """Highest contiguous cumulative level this config corresponds to."""
        lvl = 0
        for name in OPT_ORDER:
            if getattr(self, name):
                lvl += 1
            else:
                break
        return lvl

    def variant_name(self) -> str:
        opts = "".join("1" if getattr(self, name) else "0" for name in OPT_ORDER)
        epi = "" if self.epilogue == "none" else f"_{self.epilogue}"
        return (
            f"matmul_m{self.m}n{self.n}k{self.k}_{self.dtype_in}_{self.dtype_acc}"
            f"_tb{'x'.join(map(str, self.tile_tb))}"
            f"_w{'x'.join(map(str, self.tile_warp))}_o{opts}{epi}"
        )


@dataclass
class PipelineResult:
    config: PipelineConfig
    module: Module
    snapshots: Dict[str, str] = field(default_factory=dict)
    passes_run: List[str] = field(default_factory=list)


def run_pipeline(
    config: PipelineConfig,
    capture_snapshots: bool = False,
    verify: bool = False,
    verify_rng: Optional[np.random.Generator] = None,
) -> PipelineResult:
    """Run the lowering pipeline for ``config`` and return the final module."""
    config.validate()

    if config.epilogue == "none":
        mod = build_naive_matmul(config.m, config.n, config.k, config.dtype_in, config.dtype_acc)
    else:
        mod = build_fused_matmul_bias_relu(
            config.m,
            config.n,
            config.k,
            config.dtype_in,
            config.dtype_acc,
            relu=config.epilogue == "bias_relu",
        )
    mod.meta.update(
        {
            "tile_tb": config.tile_tb,
            "tile_warp": config.tile_warp,
            "pad_factor": config.pad_factor,
            "vec_width": config.vec_width,
        }
    )

    result = PipelineResult(config=config, module=mod)

    # Reference output for verification, computed on the naive module once.
    ref_out = None
    rng = verify_rng or np.random.default_rng(0)
    if verify:
        va = rng.standard_normal((config.m, config.k))
        vb = rng.standard_normal((config.k, config.n))
        vc = rng.standard_normal((config.m, config.n))
        ref_out = va @ vb + vc

    def record(name: str, semantically_complete: bool = True) -> None:
        result.passes_run.append(name)
        if capture_snapshots:
            result.snapshots[name] = print_module(mod)
        if verify and semantically_complete and config.epilogue == "none":
            got = run_matmul_module(mod, va, vb, vc.copy())
            np.testing.assert_allclose(got, ref_out, rtol=1e-10, atol=1e-10)

    record("build_naive")

    if config.tiling:
        P.two_level_tiling(mod)
        record("two_level_tiling")
    if config.shared_mem:
        P.create_shared_buffers(mod)
        record("create_shared_buffers")
    if config.wmma:
        P.generate_wmma_ops(mod, config.wmma_mnk)
        record("generate_wmma_ops")
    if config.unroll_hoist:
        P.permute_for_gpu_hierarchy(mod)
        record("permute_for_gpu_hierarchy")
        P.unroll_and_hoist(mod)
        record("unroll_and_hoist")
    if config.latency_hiding:
        # §3.5's split leaves the IR transiently incorrect under sequential
        # semantics (the paper notes decoupling is required for correctness);
        # verification resumes after decouple_copy_stores.
        P.split_main_k_loop(mod)
        record("split_main_k_loop", semantically_complete=False)
    if config.shared_mem:
        P.insert_barriers(mod)
        record(
            "insert_barriers",
            semantically_complete=not config.latency_hiding,
        )
    if config.padding:
        P.pad_shared_buffers(mod, config.pad_factor)
        record("pad_shared_buffers", semantically_complete=not config.latency_hiding)
    if config.vectorize:
        P.vectorize_copies(mod, config.vec_width)
        record("vectorize_copies", semantically_complete=not config.latency_hiding)
    if config.latency_hiding:
        P.decouple_copy_stores(mod)
        record("decouple_copy_stores")
    P.extract_and_map_parallel(mod)
    record("extract_and_map_parallel")

    return result
