"""Reference interpreter for tile-IR over numpy arrays.

This is the semantic ground truth used to verify that every pipeline pass
preserves the computation: after each pass, the module is interpreted on
random inputs and compared against the naive result.  WMMA fragments are
interpreted as dense (m, n) numpy sub-arrays, matching the warp-synchronous
"a fragment is a value held by the warp" semantics.

Interpretation happens in the accumulator dtype widened to f32/f64 on the
host; dtype rounding effects are validated separately at the Pallas level
against ``ref.py``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .ir import (
    AddF,
    Barrier,
    For,
    FpExt,
    Load,
    Module,
    MulF,
    Op,
    Store,
    VecLoad,
    VecStore,
    WmmaLoad,
    WmmaMma,
    WmmaStore,
    Yield,
)


class InterpError(RuntimeError):
    pass


class Interpreter:
    """Executes a tile-IR module against named numpy buffers."""

    def __init__(self, mod: Module, buffers: Dict[str, np.ndarray]):
        self.mod = mod
        # Physical buffers, including shared-memory scratch with padding.
        self.buffers: Dict[str, np.ndarray] = {}
        for m in mod.memrefs:
            if m.name in buffers:
                arr = buffers[m.name]
                if tuple(arr.shape) != m.shape:
                    raise InterpError(
                        f"buffer {m.name}: expected {m.shape}, got {arr.shape}"
                    )
                if m.lead_pad:
                    phys = np.zeros(m.phys_shape, dtype=arr.dtype)
                    phys[:, : m.shape[1]] = arr
                    self.buffers[m.name] = phys
                else:
                    self.buffers[m.name] = arr
            else:
                # Shared / scratch buffers start uninitialized (zeros).
                self.buffers[m.name] = np.zeros(m.phys_shape, dtype=np.float64)
        self.barrier_count = 0

    # -- public -------------------------------------------------------------
    def run(self) -> None:
        env: Dict[str, object] = {}
        for op in self.mod.body:
            self._exec(op, env)

    def result(self, name: str) -> np.ndarray:
        m = next(mr for mr in self.mod.memrefs if mr.name == name)
        return np.asarray(self.buffers[name])[:, : m.shape[1]]

    # -- execution ----------------------------------------------------------
    def _exec(self, op: Op, env: Dict[str, object]) -> None:
        if isinstance(op, For):
            self._exec_for(op, env)
        elif isinstance(op, Load):
            i, j = (e.eval(env) for e in op.idxs)  # type: ignore[arg-type]
            self._bounds_check(op.memref, i, j)
            env[op.result] = self.buffers[op.memref.name][i, j]
        elif isinstance(op, Store):
            i, j = (e.eval(env) for e in op.idxs)  # type: ignore[arg-type]
            self._bounds_check(op.memref, i, j)
            self.buffers[op.memref.name][i, j] = env[op.value]
        elif isinstance(op, VecLoad):
            i, j = (e.eval(env) for e in op.idxs)  # type: ignore[arg-type]
            self._bounds_check(op.memref, i, j + op.width - 1)
            env[op.result] = np.array(
                self.buffers[op.memref.name][i, j : j + op.width]
            )
        elif isinstance(op, VecStore):
            i, j = (e.eval(env) for e in op.idxs)  # type: ignore[arg-type]
            self._bounds_check(op.memref, i, j + op.width - 1)
            self.buffers[op.memref.name][i, j : j + op.width] = env[op.value]
        elif isinstance(op, FpExt):
            env[op.result] = float(env[op.operand])  # widening is a no-op here
        elif isinstance(op, MulF):
            env[op.result] = env[op.lhs] * env[op.rhs]
        elif isinstance(op, AddF):
            env[op.result] = env[op.lhs] + env[op.rhs]
        elif isinstance(op, WmmaLoad):
            i, j = (e.eval(env) for e in op.idxs)  # type: ignore[arg-type]
            h, w = op.shape
            self._bounds_check(op.memref, i + h - 1, j + w - 1)
            env[op.result] = np.array(
                self.buffers[op.memref.name][i : i + h, j : j + w], dtype=np.float64
            )
        elif isinstance(op, WmmaStore):
            i, j = (e.eval(env) for e in op.idxs)  # type: ignore[arg-type]
            h, w = op.shape
            self._bounds_check(op.memref, i + h - 1, j + w - 1)
            self.buffers[op.memref.name][i : i + h, j : j + w] = env[op.value]
        elif isinstance(op, WmmaMma):
            a = env[op.a]
            b = env[op.b]
            c = env[op.c]
            env[op.result] = a @ b + c
        elif isinstance(op, Barrier):
            self.barrier_count += 1
        elif isinstance(op, Yield):
            env["__yield__"] = tuple(env[v] for v in op.values)
        else:
            raise InterpError(f"cannot interpret {type(op).__name__}")

    def _exec_for(self, loop: For, env: Dict[str, object]) -> None:
        lo = loop.lb.eval(env)  # type: ignore[arg-type]
        hi = loop.ub.eval(env)  # type: ignore[arg-type]
        carried = [env[init] for _, init in loop.iter_args]
        for ivval in range(lo, hi, loop.step):
            inner = dict(env)
            inner[loop.iv] = ivval
            for (arg_name, _), val in zip(loop.iter_args, carried):
                inner[arg_name] = val
            inner.pop("__yield__", None)
            for op in loop.body:
                self._exec(op, inner)
            if loop.iter_args:
                y = inner.get("__yield__")
                if y is None or len(y) != len(loop.iter_args):
                    raise InterpError(
                        f"loop {loop.iv} with iter_args must yield "
                        f"{len(loop.iter_args)} values"
                    )
                carried = list(y)
        for name, val in zip(loop.result_names, carried):
            env[name] = val

    def _bounds_check(self, memref, i: int, j: int) -> None:
        rows, cols = memref.phys_shape
        if not (0 <= i < rows and 0 <= j < cols):
            raise InterpError(
                f"out-of-bounds access {memref.name}[{i}, {j}] "
                f"(physical shape {memref.phys_shape})"
            )


def run_matmul_module(
    mod: Module,
    a: np.ndarray,
    b: np.ndarray,
    c: Optional[np.ndarray] = None,
    bias: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Convenience wrapper: interpret a matmul module and return C."""
    m_ = mod.meta["M"]
    n_ = mod.meta["N"]
    if c is None:
        c = np.zeros((m_, n_), dtype=np.float64)
    buffers = {"%A": np.asarray(a, dtype=np.float64),
               "%B": np.asarray(b, dtype=np.float64),
               "%C": np.array(c, dtype=np.float64)}
    if bias is not None:
        buffers["%bias"] = np.asarray(bias, dtype=np.float64).reshape(1, -1)
    interp = Interpreter(mod, buffers)
    interp.run()
    return interp.result("%C")
