"""MLIR-style textual printer for tile-IR.

Produces listings in the style of the paper's Listings 1-6 so that pipeline
snapshots are directly comparable with the published IR excerpts.  The
format is stable (used by golden tests) but intentionally not re-parsed.
"""

from __future__ import annotations

from typing import List

from .ir import (
    AddF,
    Barrier,
    For,
    FpExt,
    Load,
    Module,
    MulF,
    Op,
    Store,
    VecLoad,
    VecStore,
    WmmaLoad,
    WmmaMma,
    WmmaStore,
    Yield,
)


def _idx(op) -> str:
    return ", ".join(repr(e) for e in op.idxs)


def print_op(op: Op, indent: int = 0) -> List[str]:
    pad = "  " * indent
    if isinstance(op, Load):
        return [f"{pad}{op.result} = affine.load {op.memref.name}[{_idx(op)}] : {op.memref.type_str()}"]
    if isinstance(op, Store):
        return [f"{pad}affine.store {op.value}, {op.memref.name}[{_idx(op)}] : {op.memref.type_str()}"]
    if isinstance(op, VecLoad):
        return [
            f"{pad}{op.result} = affine.vector_load {op.memref.name}[{_idx(op)}]"
            f" : {op.memref.type_str()}, vector<{op.width}x{op.memref.dtype}>"
        ]
    if isinstance(op, VecStore):
        return [
            f"{pad}affine.vector_store {op.value}, {op.memref.name}[{_idx(op)}]"
            f" : {op.memref.type_str()}, vector<{op.width}x{op.memref.dtype}>"
        ]
    if isinstance(op, FpExt):
        return [f"{pad}{op.result} = fpext {op.operand} : {op.from_dtype} to {op.to_dtype}"]
    if isinstance(op, MulF):
        return [f"{pad}{op.result} = mulf {op.lhs}, {op.rhs} : {op.dtype}"]
    if isinstance(op, AddF):
        return [f"{pad}{op.result} = addf {op.lhs}, {op.rhs} : {op.dtype}"]
    if isinstance(op, WmmaLoad):
        frag = f"!gpu.mma_matrix<{op.shape[0]}x{op.shape[1]}x{op.memref.dtype}, \"{op.operand}\">"
        return [
            f"{pad}{op.result} = gpu.subgroup_mma_load_matrix {op.memref.name}[{_idx(op)}]"
            f" {{leadDimension = {op.memref.lead_dim} : index}} : {op.memref.type_str()} -> {frag}"
        ]
    if isinstance(op, WmmaStore):
        frag = f"!gpu.mma_matrix<{op.shape[0]}x{op.shape[1]}x{op.memref.dtype}, \"COp\">"
        return [
            f"{pad}gpu.subgroup_mma_store_matrix {op.value}, {op.memref.name}[{_idx(op)}]"
            f" {{leadDimension = {op.memref.lead_dim} : index}} : {frag}, {op.memref.type_str()}"
        ]
    if isinstance(op, WmmaMma):
        m, n, k = op.mnk
        return [
            f"{pad}{op.result} = gpu.subgroup_mma_compute {op.a}, {op.b}, {op.c}"
            f" : m{m}n{n}k{k}"
        ]
    if isinstance(op, Barrier):
        return [f"{pad}gpu.barrier"]
    if isinstance(op, Yield):
        return [f"{pad}affine.yield {', '.join(op.values)}"]
    if isinstance(op, For):
        header = f"{pad}affine.for {op.iv} = {op.lb!r} to {op.ub!r}"
        if op.step != 1:
            header += f" step {op.step}"
        if op.iter_args:
            args = ", ".join(f"{n} = {init}" for n, init in op.iter_args)
            header += f" iter_args({args})"
        if op.attrs:
            attrs = ", ".join(f"{k} = \"{v}\"" for k, v in sorted(op.attrs.items()))
            header += f" {{{attrs}}}"
        lines = [header + " {"]
        for inner in op.body:
            lines.extend(print_op(inner, indent + 1))
        lines.append(pad + "}")
        return lines
    raise TypeError(f"unknown op {type(op)}")


def print_module(mod: Module) -> str:
    lines: List[str] = [f"// module @{mod.name}"]
    for m in mod.memrefs:
        if m.space == "shared":
            lines.append(
                f"memref.global \"private\" @{m.name.lstrip('%')} : {m.type_str()}"
            )
    lines.append(f"func @main() {{")
    for op in mod.body:
        lines.extend(print_op(op, 1))
    lines.append("}")
    return "\n".join(lines) + "\n"
