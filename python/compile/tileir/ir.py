"""Core tile-IR: a small affine-dialect-style IR for the matmul pipeline.

This mirrors the subset of MLIR the paper (Katel et al., 2021) actually
uses: perfectly-nestable ``affine.for`` loops with affine bounds and index
expressions, loads/stores on memrefs with layout padding, scalar arithmetic,
WMMA fragment ops (``gpu.subgroup_mma_*`` analogs), barriers, and vectorized
memory ops.  Everything the ten pipeline passes in ``tileir.passes``
transform is represented here.

Design notes
------------
* Index arithmetic is restricted to affine expressions over loop induction
  variables (integer coefficients + constant), which is exactly the class
  MLIR's affine dialect guarantees and all of the paper's transformations
  stay inside.
* SSA is lightweight: each op producing a value carries a fresh ``result``
  name; uses refer to names.  Passes that clone/substitute are responsible
  for renaming (helpers below).
* Memory spaces follow the GPU model of the paper: ``global`` (HBM),
  ``shared`` (CUDA shared memory / VMEM in the TPU adaptation), ``reg``
  (register fragments).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Types
# ---------------------------------------------------------------------------

F16 = "f16"
F32 = "f32"
BF16 = "bf16"

_DTYPE_BYTES = {F16: 2, BF16: 2, F32: 4}


def dtype_bytes(dtype: str) -> int:
    """Size in bytes of one element of ``dtype``."""
    return _DTYPE_BYTES[dtype]


_name_counter = itertools.count()


def fresh_name(prefix: str) -> str:
    """Return a module-unique SSA name like ``%a12``."""
    return f"%{prefix}{next(_name_counter)}"


# ---------------------------------------------------------------------------
# Affine expressions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AffineExpr:
    """Linear expression ``sum(coeff_i * iv_i) + const`` over loop IVs."""

    terms: Tuple[Tuple[str, int], ...] = ()
    const: int = 0

    # -- constructors -------------------------------------------------------
    @staticmethod
    def var(name: str, coeff: int = 1) -> "AffineExpr":
        return AffineExpr(terms=((name, coeff),), const=0)

    @staticmethod
    def cst(value: int) -> "AffineExpr":
        return AffineExpr(terms=(), const=value)

    # -- algebra ------------------------------------------------------------
    def _as_dict(self) -> Dict[str, int]:
        d: Dict[str, int] = {}
        for name, c in self.terms:
            d[name] = d.get(name, 0) + c
        return {k: v for k, v in d.items() if v != 0}

    def __add__(self, other: "AffineExpr | int") -> "AffineExpr":
        if isinstance(other, int):
            other = AffineExpr.cst(other)
        d = self._as_dict()
        for name, c in other.terms:
            d[name] = d.get(name, 0) + c
        terms = tuple(sorted((k, v) for k, v in d.items() if v != 0))
        return AffineExpr(terms=terms, const=self.const + other.const)

    def __sub__(self, other: "AffineExpr | int") -> "AffineExpr":
        if isinstance(other, int):
            return self + (-other)
        neg = AffineExpr(
            terms=tuple((n, -c) for n, c in other.terms), const=-other.const
        )
        return self + neg

    def scaled(self, factor: int) -> "AffineExpr":
        return AffineExpr(
            terms=tuple((n, c * factor) for n, c in self.terms),
            const=self.const * factor,
        )

    # -- queries ------------------------------------------------------------
    def vars(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.terms)

    def is_const(self) -> bool:
        return not self.terms

    def coeff(self, name: str) -> int:
        return self._as_dict().get(name, 0)

    def eval(self, env: Dict[str, int]) -> int:
        total = self.const
        for name, c in self.terms:
            total += c * env[name]
        return total

    # -- substitution -------------------------------------------------------
    def subst(self, mapping: Dict[str, "AffineExpr"]) -> "AffineExpr":
        """Replace each IV in ``mapping`` by the given expression."""
        out = AffineExpr.cst(self.const)
        for name, c in self.terms:
            if name in mapping:
                out = out + mapping[name].scaled(c)
            else:
                out = out + AffineExpr.var(name, c)
        return out

    def subst_const(self, name: str, value: int) -> "AffineExpr":
        return self.subst({name: AffineExpr.cst(value)})

    def __repr__(self) -> str:  # MLIR-ish rendering, used by the printer
        parts: List[str] = []
        for name, c in self.terms:
            if c == 1:
                parts.append(name)
            elif c == -1:
                parts.append(f"-{name}")
            else:
                parts.append(f"{c} * {name}")
        if self.const or not parts:
            parts.append(str(self.const))
        s = parts[0]
        for p in parts[1:]:
            s += f" - {p[1:]}" if p.startswith("-") else f" + {p}"
        return s


# ---------------------------------------------------------------------------
# MemRefs
# ---------------------------------------------------------------------------


@dataclass
class MemRef:
    """A 2-D memref with an optional padded leading dimension.

    ``shape`` is the logical (rows, cols) shape.  ``lead_pad`` extends the
    row stride: the physical buffer is ``rows x (cols + lead_pad)`` — the
    paper's shared-memory padding trick (§3.3), expressed as a layout-map
    change so no other IR needs to change.
    """

    name: str
    shape: Tuple[int, int]
    dtype: str
    space: str = "global"  # global | shared | reg
    lead_pad: int = 0

    @property
    def lead_dim(self) -> int:
        """Row stride in elements (the WMMA ``leadDimension`` attribute)."""
        return self.shape[1] + self.lead_pad

    @property
    def phys_shape(self) -> Tuple[int, int]:
        return (self.shape[0], self.lead_dim)

    def size_bytes(self) -> int:
        return self.phys_shape[0] * self.phys_shape[1] * dtype_bytes(self.dtype)

    def type_str(self) -> str:
        space = {"global": "", "shared": ", 3", "reg": ", 5"}[self.space]
        return f"memref<{self.phys_shape[0]}x{self.phys_shape[1]}x{self.dtype}{space}>"


# ---------------------------------------------------------------------------
# Ops
# ---------------------------------------------------------------------------


@dataclass
class Op:
    """Base class for all tile-IR operations."""

    def clone(self) -> "Op":
        raise NotImplementedError

    def results(self) -> List[str]:
        return []

    def operands(self) -> List[str]:
        return []


@dataclass
class Load(Op):
    result: str
    memref: MemRef
    idxs: Tuple[AffineExpr, AffineExpr]

    def clone(self) -> "Load":
        return Load(self.result, self.memref, self.idxs)

    def results(self) -> List[str]:
        return [self.result]


@dataclass
class Store(Op):
    value: str
    memref: MemRef
    idxs: Tuple[AffineExpr, AffineExpr]

    def clone(self) -> "Store":
        return Store(self.value, self.memref, self.idxs)

    def operands(self) -> List[str]:
        return [self.value]


@dataclass
class VecLoad(Op):
    """Vector load of ``width`` contiguous elements starting at idxs."""

    result: str
    memref: MemRef
    idxs: Tuple[AffineExpr, AffineExpr]
    width: int

    def clone(self) -> "VecLoad":
        return VecLoad(self.result, self.memref, self.idxs, self.width)

    def results(self) -> List[str]:
        return [self.result]


@dataclass
class VecStore(Op):
    value: str
    memref: MemRef
    idxs: Tuple[AffineExpr, AffineExpr]
    width: int

    def clone(self) -> "VecStore":
        return VecStore(self.value, self.memref, self.idxs, self.width)

    def operands(self) -> List[str]:
        return [self.value]


@dataclass
class FpExt(Op):
    result: str
    operand: str
    from_dtype: str = F16
    to_dtype: str = F32

    def clone(self) -> "FpExt":
        return FpExt(self.result, self.operand, self.from_dtype, self.to_dtype)

    def results(self) -> List[str]:
        return [self.result]

    def operands(self) -> List[str]:
        return [self.operand]


@dataclass
class MulF(Op):
    result: str
    lhs: str
    rhs: str
    dtype: str = F32

    def clone(self) -> "MulF":
        return MulF(self.result, self.lhs, self.rhs, self.dtype)

    def results(self) -> List[str]:
        return [self.result]

    def operands(self) -> List[str]:
        return [self.lhs, self.rhs]


@dataclass
class AddF(Op):
    result: str
    lhs: str
    rhs: str
    dtype: str = F32

    def clone(self) -> "AddF":
        return AddF(self.result, self.lhs, self.rhs, self.dtype)

    def results(self) -> List[str]:
        return [self.result]

    def operands(self) -> List[str]:
        return [self.lhs, self.rhs]


@dataclass
class WmmaLoad(Op):
    """``gpu.subgroup_mma_load_matrix`` — load a fragment into registers.

    ``operand`` is one of "AOp" | "BOp" | "COp"; ``shape`` is the fragment
    (m, n) footprint in the source memref.
    """

    result: str
    memref: MemRef
    idxs: Tuple[AffineExpr, AffineExpr]
    operand: str
    shape: Tuple[int, int]

    def clone(self) -> "WmmaLoad":
        return WmmaLoad(self.result, self.memref, self.idxs, self.operand, self.shape)

    def results(self) -> List[str]:
        return [self.result]


@dataclass
class WmmaStore(Op):
    """``gpu.subgroup_mma_store_matrix`` — store a COp fragment."""

    value: str
    memref: MemRef
    idxs: Tuple[AffineExpr, AffineExpr]
    shape: Tuple[int, int]

    def clone(self) -> "WmmaStore":
        return WmmaStore(self.value, self.memref, self.idxs, self.shape)

    def operands(self) -> List[str]:
        return [self.value]


@dataclass
class WmmaMma(Op):
    """``gpu.subgroup_mma_compute``: D = A * B + C on one fragment triple."""

    result: str
    a: str
    b: str
    c: str
    mnk: Tuple[int, int, int] = (16, 16, 16)

    def clone(self) -> "WmmaMma":
        return WmmaMma(self.result, self.a, self.b, self.c, self.mnk)

    def results(self) -> List[str]:
        return [self.result]

    def operands(self) -> List[str]:
        return [self.a, self.b, self.c]


@dataclass
class Barrier(Op):
    """``gpu.barrier`` / ``__syncthreads()``."""

    def clone(self) -> "Barrier":
        return Barrier()


@dataclass
class Yield(Op):
    values: Tuple[str, ...] = ()

    def clone(self) -> "Yield":
        return Yield(self.values)

    def operands(self) -> List[str]:
        return list(self.values)


@dataclass
class For(Op):
    """``affine.for %iv = lb to ub step s`` with optional iter_args.

    ``iter_args`` is a list of (block_arg_name, init_value_name).  When
    present the body must end with a ``Yield`` of matching arity, and the
    loop's ``result_names`` expose the final values to the enclosing region.
    ``attrs`` carries pass-to-pass metadata: ``role`` ("copyA", "copyB",
    "compute", "main_k", "warp_k", ...), ``parallel`` mapping ("block_x",
    "block_y", "warp_x", "warp_y"), etc.
    """

    iv: str
    lb: AffineExpr
    ub: AffineExpr
    step: int
    body: List[Op] = field(default_factory=list)
    iter_args: List[Tuple[str, str]] = field(default_factory=list)
    result_names: List[str] = field(default_factory=list)
    attrs: Dict[str, str] = field(default_factory=dict)

    def clone(self) -> "For":
        return For(
            iv=self.iv,
            lb=self.lb,
            ub=self.ub,
            step=self.step,
            body=[op.clone() for op in self.body],
            iter_args=list(self.iter_args),
            result_names=list(self.result_names),
            attrs=dict(self.attrs),
        )

    def results(self) -> List[str]:
        return list(self.result_names)

    def trip_count(self, env: Optional[Dict[str, int]] = None) -> int:
        env = env or {}
        lo, hi = self.lb.eval(env), self.ub.eval(env)
        return max(0, (hi - lo + self.step - 1) // self.step)


# ---------------------------------------------------------------------------
# Module
# ---------------------------------------------------------------------------


@dataclass
class Module:
    """Top-level container: memref declarations + a single loop-nest body.

    ``roles`` names the operand memrefs ("A", "B", "C" and, after the buffer
    pass, "a_smem"/"b_smem") so passes can find them without pattern
    matching on names.
    """

    name: str
    memrefs: List[MemRef] = field(default_factory=list)
    body: List[Op] = field(default_factory=list)
    roles: Dict[str, MemRef] = field(default_factory=dict)
    meta: Dict[str, object] = field(default_factory=dict)

    def add_memref(self, m: MemRef, role: Optional[str] = None) -> MemRef:
        self.memrefs.append(m)
        if role is not None:
            self.roles[role] = m
        return m

    def clone(self) -> "Module":
        mod = Module(
            name=self.name,
            memrefs=list(self.memrefs),
            body=[op.clone() for op in self.body],
            roles=dict(self.roles),
            meta=dict(self.meta),
        )
        return mod

    # -- traversal helpers ---------------------------------------------------
    def walk(self) -> Iterable[Op]:
        """Pre-order walk of every op in the module."""

        def _walk(ops: Sequence[Op]) -> Iterable[Op]:
            for op in ops:
                yield op
                if isinstance(op, For):
                    yield from _walk(op.body)

        yield from _walk(self.body)

    def find_loops(self, **attr_filters: str) -> List[For]:
        """All loops whose attrs contain every given key=value."""
        out = []
        for op in self.walk():
            if isinstance(op, For) and all(
                op.attrs.get(k) == v for k, v in attr_filters.items()
            ):
                out.append(op)
        return out

    def loop_nest(self) -> List[For]:
        """The outermost perfect loop nest (follows single-For bodies)."""
        nest: List[For] = []
        ops = self.body
        while True:
            fors = [op for op in ops if isinstance(op, For)]
            if len(fors) != 1:
                break
            nest.append(fors[0])
            ops = fors[0].body
        return nest


# ---------------------------------------------------------------------------
# Structural helpers shared by passes
# ---------------------------------------------------------------------------


def subst_exprs(op: Op, mapping: Dict[str, AffineExpr]) -> None:
    """In-place substitution of IVs inside all affine index expressions."""
    if isinstance(op, (Load, Store, VecLoad, VecStore, WmmaLoad, WmmaStore)):
        op.idxs = tuple(e.subst(mapping) for e in op.idxs)  # type: ignore[assignment]
    if isinstance(op, For):
        op.lb = op.lb.subst(mapping)
        op.ub = op.ub.subst(mapping)
        for inner in op.body:
            subst_exprs(inner, mapping)


def rename_values(op: Op, mapping: Dict[str, str]) -> None:
    """In-place renaming of SSA value names (results and operands)."""
    if isinstance(op, (Load, VecLoad, WmmaLoad, FpExt, MulF, AddF, WmmaMma)):
        if op.result in mapping:
            op.result = mapping[op.result]
    if isinstance(op, (Store, VecStore, WmmaStore)):
        if op.value in mapping:
            op.value = mapping[op.value]
    if isinstance(op, FpExt) and op.operand in mapping:
        op.operand = mapping[op.operand]
    if isinstance(op, (MulF, AddF)):
        op.lhs = mapping.get(op.lhs, op.lhs)
        op.rhs = mapping.get(op.rhs, op.rhs)
    if isinstance(op, WmmaMma):
        op.a = mapping.get(op.a, op.a)
        op.b = mapping.get(op.b, op.b)
        op.c = mapping.get(op.c, op.c)
    if isinstance(op, Yield):
        op.values = tuple(mapping.get(v, v) for v in op.values)
    if isinstance(op, For):
        op.iter_args = [
            (mapping.get(n, n), mapping.get(init, init)) for n, init in op.iter_args
        ]
        op.result_names = [mapping.get(n, n) for n in op.result_names]
        for inner in op.body:
            rename_values(inner, mapping)


def clone_with_fresh_names(ops: Sequence[Op], suffix: str) -> List[Op]:
    """Clone a list of ops, freshening every SSA result name.

    Used by unrolling: each unrolled copy of the body needs distinct names.
    """
    clones = [op.clone() for op in ops]
    mapping: Dict[str, str] = {}

    def collect(op: Op) -> None:
        for r in op.results():
            mapping[r] = f"{r}_{suffix}"
        if isinstance(op, For):
            for inner in op.body:
                collect(inner)

    for op in clones:
        collect(op)
    for op in clones:
        rename_values(op, mapping)
    return clones
