"""Schedule extraction: the contract between the pipeline and the backends.

After the pipeline runs, the final module's structure encodes everything a
backend needs: tile sizes, fragment shape, padding, pipelining depth,
vector width, launch geometry, and shared-memory footprint.  ``Schedule``
extracts those into a plain record consumed by

* the Pallas emitter (``kernels/emitter.py``) — grid + BlockSpecs;
* the Rust performance simulator — cost-model inputs (serialized into
  ``artifacts/manifest.json`` by ``aot.py`` and re-parsed by
  ``rust/src/schedule.rs``).

Extraction cross-checks the module meta against the IR itself (buffer
shapes, barrier counts, peeled stages) so a pass that silently diverged
from its declared effect fails here rather than downstream.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional, Tuple

from .ir import Barrier, For, Module, VecLoad, dtype_bytes


class ScheduleError(ValueError):
    pass


@dataclass(frozen=True)
class Schedule:
    """Backend-facing description of one generated kernel variant."""

    name: str
    m: int
    n: int
    k: int
    dtype_in: str
    dtype_acc: str
    epilogue: str
    # Optimization structure
    opt_level: int
    tiling: bool
    shared_mem: bool
    wmma: bool
    unroll_hoist: bool
    latency_hiding: bool
    padding: bool
    vectorize: bool
    # Tiling parameters
    tile_tb: Tuple[int, int, int]
    tile_warp: Tuple[int, int, int]
    wmma_mnk: Tuple[int, int, int]
    pad_factor: int
    vec_width: int
    pipeline_stages: int
    # Launch geometry
    grid: Tuple[int, int]
    warps_per_block: Tuple[int, int]
    threads_per_block: int
    # Derived footprints
    smem_bytes: int
    accumulators_per_warp: int
    barriers_per_iteration: int

    def to_json_dict(self) -> Dict:
        return asdict(self)

    def flops(self) -> int:
        return 2 * self.m * self.n * self.k

    def vmem_tile_bytes(self) -> int:
        """VMEM footprint of one grid cell in the Pallas/TPU adaptation:
        A tile + B tile + C accumulator tile (padded)."""
        tbm, tbn, tbk = self.tile_tb
        in_b = dtype_bytes(self.dtype_in)
        acc_b = dtype_bytes(self.dtype_acc)
        pad = self.pad_factor if self.padding else 0
        a = tbm * (tbk + pad) * in_b
        b = tbk * (tbn + pad) * in_b
        c = tbm * tbn * acc_b
        return a + b + c


def _count_steady_barriers(mod: Module) -> int:
    k_loops = mod.find_loops(role="main_k")
    if not k_loops:
        return 0
    return sum(1 for op in k_loops[0].body if isinstance(op, Barrier))


def extract_schedule(mod: Module, config) -> Schedule:
    """Build a Schedule from a completed pipeline module + its config."""
    meta = mod.meta
    if not meta.get("parallelized"):
        raise ScheduleError("schedule extraction requires a completed pipeline")

    # Cross-check shared-memory footprint against the actual buffers.
    smem_bytes = sum(m.size_bytes() for m in mod.memrefs if m.space == "shared")
    if config.shared_mem:
        tbm, tbn, tbk = config.tile_tb
        pad = config.pad_factor if config.padding else 0
        expect = (tbm * (tbk + pad) + tbk * (tbn + pad)) * dtype_bytes(
            config.dtype_in
        )
        if smem_bytes != expect:
            raise ScheduleError(
                f"shared-memory footprint mismatch: IR has {smem_bytes} B, "
                f"config implies {expect} B"
            )

    # Cross-check pipelining: a latency-split module must have prologue and
    # epilogue stages in the IR.
    stages = int(meta.get("pipeline_stages", 1))
    if config.latency_hiding:
        pro = [
            op for op in mod.walk()
            if isinstance(op, For) and op.attrs.get("stage") == "prologue"
        ]
        epi = [
            op for op in mod.walk()
            if isinstance(op, For) and op.attrs.get("stage") == "epilogue"
        ]
        if not pro or not epi:
            raise ScheduleError("latency-hidden module missing peeled stages")
        if not meta.get("decoupled"):
            raise ScheduleError("latency-hidden module missing decoupled stores")

    # Cross-check vectorization against the IR.
    vec_width = int(meta.get("vec_width", 1)) if config.vectorize else 1
    if config.vectorize:
        vec_loads = [op for op in mod.walk() if isinstance(op, VecLoad)]
        if not vec_loads:
            raise ScheduleError("vectorized module contains no vector loads")

    wmma_mnk = tuple(meta.get("wmma_mnk", (16, 16, 16)))
    wm, wn, _ = config.tile_warp
    acc = (
        (wm // wmma_mnk[0]) * (wn // wmma_mnk[1])
        if config.wmma
        else 0
    )
    if config.unroll_hoist and meta.get("num_accumulators") != acc:
        raise ScheduleError(
            f"accumulator count mismatch: IR has {meta.get('num_accumulators')}, "
            f"config implies {acc}"
        )

    return Schedule(
        name=config.variant_name(),
        m=config.m,
        n=config.n,
        k=config.k,
        dtype_in=config.dtype_in,
        dtype_acc=config.dtype_acc,
        epilogue=config.epilogue,
        opt_level=config.level(),
        tiling=config.tiling,
        shared_mem=config.shared_mem,
        wmma=config.wmma,
        unroll_hoist=config.unroll_hoist,
        latency_hiding=config.latency_hiding,
        padding=config.padding,
        vectorize=config.vectorize,
        tile_tb=tuple(config.tile_tb),
        tile_warp=tuple(config.tile_warp),
        wmma_mnk=wmma_mnk,
        pad_factor=config.pad_factor if config.padding else 0,
        vec_width=vec_width,
        pipeline_stages=stages if config.latency_hiding else 1,
        grid=tuple(meta["grid"]),
        warps_per_block=tuple(meta["warps_per_block"]),
        threads_per_block=int(meta["threads_per_block"]),
        smem_bytes=smem_bytes,
        accumulators_per_warp=acc,
        barriers_per_iteration=_count_steady_barriers(mod),
    )
