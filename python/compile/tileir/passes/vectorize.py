"""§3.7 — Vectorize the global↔shared copy loops.

Scalar copy loads/stores become ``width``-element vector ops (128-bit for
f16 at width 8, the configuration the paper found best).  The innermost
copy loop must be unit-stride in the last memref dimension of both source
and destination, and the trip count, destination padding, and leading
dimensions must all be multiples of the vector width.
"""

from __future__ import annotations

from ..ir import For, Load, Module, Store, VecLoad, VecStore, dtype_bytes


class VectorizeError(ValueError):
    pass


def _vectorize_nest(nest: For, width: int) -> None:
    inner = nest
    while inner.body and isinstance(inner.body[0], For):
        inner = inner.body[0]
    loads = [op for op in inner.body if isinstance(op, Load)]
    stores = [op for op in inner.body if isinstance(op, Store)]
    if len(loads) != 1 or len(stores) != 1:
        raise VectorizeError(f"copy nest {nest.attrs.get('role')} not a load/store pair")
    ld, st = loads[0], stores[0]

    iv = inner.iv
    if ld.idxs[1].coeff(iv) != 1 or st.idxs[1].coeff(iv) != 1:
        raise VectorizeError(
            f"innermost copy loop {iv} is not unit-stride in the last dimension"
        )
    span_expr = inner.ub - inner.lb  # bounds may share loop-invariant vars
    if span_expr.terms:
        raise VectorizeError(f"copy loop {iv} has a non-constant span")
    span = span_expr.const
    if span % width != 0:
        raise VectorizeError(f"copy span {span} not a multiple of width {width}")
    for memref in (ld.memref, st.memref):
        if memref.lead_dim % width != 0:
            raise VectorizeError(
                f"{memref.name} leading dimension {memref.lead_dim} not a "
                f"multiple of vector width {width}"
            )

    inner.step = width
    inner.body = [
        VecLoad(ld.result, ld.memref, ld.idxs, width),
        VecStore(st.value, st.memref, st.idxs, width),
    ]
    nest.attrs["vectorized"] = str(width)


def vectorize_copies(mod: Module, width: int | None = None) -> Module:
    if not mod.meta.get("shared_mem"):
        raise VectorizeError("vectorize_copies requires shared-memory staging")
    width = width if width is not None else int(mod.meta.get("vec_width", 8))
    dtype = mod.roles["A"].dtype
    if width * dtype_bytes(dtype) not in (4, 8, 16):
        raise VectorizeError(
            f"vector width {width} x {dtype} is not a 32/64/128-bit access"
        )

    nests = [
        op
        for op in mod.walk()
        if isinstance(op, For)
        and op.attrs.get("role", "") in ("copyA", "copyB")
    ]
    if not nests:
        raise VectorizeError("no copy nests found")
    for nest in nests:
        _vectorize_nest(nest, width)

    mod.meta["vectorized"] = True
    mod.meta["vec_width"] = width
    return mod
