"""§3.6 — Insert synchronization barriers around shared-memory traffic.

All threads of a block cooperate on the shared-memory copies, so a barrier
is needed (a) before the copies overwrite the buffers a previous iteration
may still be reading, and (b) after the copies, before any thread reads the
freshly staged tiles.  As in the paper, placement uses the static structure
of the copy loops rather than a general dependence analysis.

For the latency-split form (§3.5) the placement follows Listing 6: one
barrier after the prologue copies, one at the top of the steady-state body,
one between compute and the delayed stores (added by
``decouple_copy_stores``), and one after the main loop before the peeled
compute.
"""

from __future__ import annotations

from typing import List

from ..ir import Barrier, For, Module, Op


class BarrierError(ValueError):
    pass


def insert_barriers(mod: Module) -> Module:
    if not mod.meta.get("shared_mem"):
        raise BarrierError("insert_barriers requires shared-memory staging")

    k = mod.find_loops(role="main_k")[0]

    if mod.meta.get("latency_split"):
        jj = mod.find_loops(role="warp_j")[0]
        # Barrier after the prologue copies (before entering the k-loop).
        prologue = [
            op
            for op in jj.body
            if isinstance(op, For) and op.attrs.get("stage") == "prologue"
        ]
        if not prologue:
            raise BarrierError("latency-split module missing prologue copies")
        at = jj.body.index(prologue[-1]) + 1
        jj.body = jj.body[:at] + [Barrier()] + jj.body[at:]
        # Barrier at the top of the steady-state body (previous iteration's
        # delayed stores must be visible before this iteration's compute).
        k.body = [Barrier()] + k.body
        # Barrier after the k-loop, before the peeled compute.
        epi = [
            op
            for op in jj.body
            if isinstance(op, For) and op.attrs.get("stage") == "epilogue"
        ]
        if not epi:
            raise BarrierError("latency-split module missing peeled compute")
        at = jj.body.index(epi[0])
        jj.body = jj.body[:at] + [Barrier()] + jj.body[at:]
    else:
        # Algorithm 1 placement: barrier, copies, barrier, compute.
        copies: List[Op] = [
            op
            for op in k.body
            if isinstance(op, For) and op.attrs.get("role", "").startswith("copy")
        ]
        if not copies:
            raise BarrierError("no copy loops found in main k-loop")
        last_idx = max(k.body.index(c) for c in copies)
        first_idx = min(k.body.index(c) for c in copies)
        body = list(k.body)
        body.insert(last_idx + 1, Barrier())
        body.insert(first_idx, Barrier())
        k.body = body

    mod.meta["barriers"] = True
    return mod
