"""§3.4 — Full unroll of the fragment loops, CSE, and C hoisting.

After permutation the nest is ``i, j, ii, jj, k(copies, kk(kkk, iii, jjj))``
with a WMMA body.  This pass:

1. fully unrolls the three fragment loops inside the warp k-loop, revealing
   all fragment loads;
2. CSEs duplicate fragment loads (an A fragment is re-loaded for every
   jjj, a B fragment for every iii, a C fragment for every kkk — the
   paper's "unroll-jam kind of effect");
3. observes that the C fragment load/stores are invariant in ``k``/``kk``,
   hoists the loads above the main k-loop and the stores below it, and
   threads the live accumulator fragments through both k-loops as
   ``iter_args`` — the registers that accumulate across the whole K
   dimension (Listing 3).  CSE of a C load across the intervening fragment
   store is legal precisely because the store/load round-trip through C is
   replaced by direct SSA chaining of the MMA results.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from ..ir import (
    AffineExpr,
    For,
    Module,
    Op,
    WmmaLoad,
    WmmaMma,
    WmmaStore,
    Yield,
    clone_with_fresh_names,
    fresh_name,
    subst_exprs,
)


class HoistError(ValueError):
    pass


def fully_unroll(loop: For) -> List[Op]:
    """Return the flat op list of a fully unrolled constant-bounds loop."""
    if not (loop.lb.is_const() and loop.ub.is_const()):
        raise HoistError(f"cannot fully unroll loop {loop.iv}: non-constant bounds")
    if loop.iter_args:
        raise HoistError(f"cannot fully unroll loop {loop.iv}: has iter_args")
    out: List[Op] = []
    for idx, ivval in enumerate(range(loop.lb.const, loop.ub.const, loop.step)):
        clones = clone_with_fresh_names(loop.body, f"u{idx}")
        for op in clones:
            subst_exprs(op, {loop.iv: AffineExpr.cst(ivval)})
        out.extend(clones)
    return out


def _unroll_nest(loop: For) -> List[Op]:
    """Recursively unroll a loop nest into a flat op list."""
    flat: List[Op] = []
    for op in fully_unroll(loop):
        if isinstance(op, For):
            flat.extend(_unroll_nest(op))
        else:
            flat.append(op)
    return flat


def _cse_fragment_loads(ops: List[Op]) -> List[Op]:
    """Remove duplicate WMMA loads with identical source and indices."""
    seen: Dict[Tuple, str] = {}
    rename: Dict[str, str] = {}
    out: List[Op] = []
    for op in ops:
        if isinstance(op, WmmaLoad):
            key = (op.memref.name, op.operand, op.idxs, op.shape)
            if key in seen:
                rename[op.result] = seen[key]
                continue
            seen[key] = op.result
        if isinstance(op, WmmaMma):
            op.a = rename.get(op.a, op.a)
            op.b = rename.get(op.b, op.b)
            op.c = rename.get(op.c, op.c)
        if isinstance(op, WmmaStore):
            op.value = rename.get(op.value, op.value)
        out.append(op)
    return out


def unroll_and_hoist(mod: Module) -> Module:
    if not mod.meta.get("permuted"):
        raise HoistError("unroll_and_hoist requires permute_for_gpu_hierarchy")

    jj = mod.find_loops(role="warp_j")[0]
    k = mod.find_loops(role="main_k")[0]
    kk = mod.find_loops(role="warp_k")[0]
    kkk = mod.find_loops(role="frag_k")[0]
    c_ref = mod.roles["C"]

    # 1. + 2. — unroll the fragment nest and CSE the revealed loads.
    flat = _cse_fragment_loads(_unroll_nest(kkk))

    # 3. — hoist C.  Identify each C fragment by its (row, col) index
    # expressions; they must be invariant in both k-loops.
    kvars = {k.iv, kk.iv}
    frag_idxs: List[Tuple[AffineExpr, AffineExpr]] = []
    keys: List[Tuple] = []  # insertion-ordered fragment keys
    hoisted_loads: List[WmmaLoad] = []
    init_name: Dict[Tuple, str] = {}  # key -> hoisted register name
    acc_name: Dict[Tuple, str] = {}  # key -> current accumulator SSA name
    load_to_key: Dict[str, Tuple] = {}  # CSE'd C-load result -> key

    def fkey(idxs) -> Tuple:
        return tuple((e.terms, e.const) for e in idxs)

    new_body: List[Op] = []
    for op in flat:
        if isinstance(op, WmmaLoad) and op.operand == "COp":
            if any(v in kvars for e in op.idxs for v in e.vars()):
                raise HoistError("C fragment load not invariant in k-loops")
            key = fkey(op.idxs)
            if key not in init_name:
                reg = fresh_name("c_reg")
                hoisted_loads.append(WmmaLoad(reg, op.memref, op.idxs, "COp", op.shape))
                init_name[key] = reg
                acc_name[key] = reg
                keys.append(key)
                frag_idxs.append(op.idxs)
            load_to_key[op.result] = key
            continue  # the in-loop load disappears
        if isinstance(op, WmmaMma):
            if op.c in load_to_key:
                key = load_to_key[op.c]
            else:
                key = next(
                    (kx for kx, v in acc_name.items() if v == op.c), None
                )
                if key is None:
                    raise HoistError(f"cannot trace accumulator for {op.c}")
            op.c = acc_name[key]
            acc_name[key] = op.result
            new_body.append(op)
            continue
        if isinstance(op, WmmaStore) and op.memref is c_ref:
            continue  # the final store happens once, after the main k-loop
        new_body.append(op)

    if not keys:
        raise HoistError("no C fragments found to hoist")

    # Wire accumulators through kk as iter_args.  The first MMA per fragment
    # currently consumes the hoisted register name; point it at the kk block
    # argument instead.
    kk_args = [(fresh_name("acc"), init_name[key]) for key in keys]
    arg_of_init = {init_name[key]: arg for key, (arg, _) in zip(keys, kk_args)}
    for op in new_body:
        if isinstance(op, WmmaMma) and op.c in arg_of_init:
            op.c = arg_of_init[op.c]
    kk_results = [fresh_name("kkres") for _ in keys]
    kk.body = new_body + [Yield(tuple(acc_name[key] for key in keys))]
    kk.iter_args = kk_args
    kk.result_names = kk_results

    # Thread through the main k-loop: kk consumes the k block args and k
    # yields kk's results.
    k_args = [(fresh_name("c_in"), init_name[key]) for key in keys]
    remap = {init_name[key]: arg for key, (arg, _) in zip(keys, k_args)}
    kk.iter_args = [(arg, remap.get(init, init)) for arg, init in kk.iter_args]
    k_results = [fresh_name("res") for _ in keys]
    copies = [op for op in k.body if op is not kk]
    k.body = copies + [kk, Yield(tuple(kk_results))]
    k.iter_args = k_args
    k.result_names = k_results

    # Final stores after the main k-loop, at warp (jj) level.
    fm, fn = mod.meta.get("wmma_mnk", (16, 16, 16))[0], mod.meta.get(
        "wmma_mnk", (16, 16, 16)
    )[1]
    stores = [
        WmmaStore(res, c_ref, idxs, (fm, fn))
        for res, idxs in zip(k_results, frag_idxs)
    ]
    jj.body = hoisted_loads + [k] + stores

    mod.meta["hoisted"] = True
    mod.meta["num_accumulators"] = len(keys)
    return mod
