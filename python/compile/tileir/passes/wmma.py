"""§3.4 — Rewrite the scalar fragment loops into WMMA ops.

The innermost three (fragment) loops currently step by 1 with a scalar
multiply-accumulate body.  This pass bumps their steps to the WMMA intrinsic
size (m16n16k16 in the paper) and replaces the scalar body with the
fragment-level load/compute/store sequence:

    %a = gpu.subgroup_mma_load_matrix  a_src[row, col]   ("AOp")
    %b = gpu.subgroup_mma_load_matrix  b_src[row, col]   ("BOp")
    %c = gpu.subgroup_mma_load_matrix  C[row, col]       ("COp")
    %r = gpu.subgroup_mma_compute %a, %b, %c
    gpu.subgroup_mma_store_matrix %r, C[row, col]

The fragment origins are taken from the existing scalar loads' affine index
expressions, so the pass is agnostic to whether shared-memory staging
already happened (A/B may still live in global memory at this point for
ablation configurations without the buffer pass).
"""

from __future__ import annotations

from typing import Optional, Tuple

from ..ir import (
    For,
    Load,
    Module,
    WmmaLoad,
    WmmaMma,
    WmmaStore,
    fresh_name,
)


class WmmaError(ValueError):
    pass


def _find_scalar_loads(loop: For, mod: Module):
    """Locate the A-source, B-source and C loads in the scalar body."""
    a_like = {id(mod.roles["A"]): "A", id(mod.roles.get("a_smem")): "a_smem"}
    b_like = {id(mod.roles["B"]): "B", id(mod.roles.get("b_smem")): "b_smem"}
    c_ref = mod.roles["C"]
    a_load = b_load = c_load = None
    for op in loop.body:
        if isinstance(op, Load):
            if id(op.memref) in a_like:
                a_load = op
            elif id(op.memref) in b_like:
                b_load = op
            elif op.memref is c_ref:
                c_load = op
    if a_load is None or b_load is None or c_load is None:
        raise WmmaError("scalar fragment body does not match matmul pattern")
    return a_load, b_load, c_load


def generate_wmma_ops(mod: Module, mnk: Tuple[int, int, int] = (16, 16, 16)) -> Module:
    """Replace the fragment loops' scalar body with WMMA fragment ops."""
    if not mod.meta.get("tiled"):
        raise WmmaError("generate_wmma_ops requires two_level_tiling first")
    wm, wn, wk = mod.meta["tile_warp"]
    fm, fn, fk = mnk
    if wm % fm or wn % fn or wk % fk:
        raise WmmaError(f"warp tile {(wm, wn, wk)} not a multiple of WMMA {mnk}")

    frag_i = mod.find_loops(role="frag_i")
    frag_j = mod.find_loops(role="frag_j")
    frag_k = mod.find_loops(role="frag_k")
    if not (len(frag_i) == len(frag_j) == len(frag_k) == 1):
        raise WmmaError("expected exactly one fragment loop nest")
    li, lj, lk = frag_i[0], frag_j[0], frag_k[0]

    a_load, b_load, c_load = _find_scalar_loads(lk, mod)
    c_ref = mod.roles["C"]

    li.step, lj.step, lk.step = fm, fn, fk

    va, vb, vc, vr = (
        fresh_name("afrag"),
        fresh_name("bfrag"),
        fresh_name("cfrag"),
        fresh_name("dfrag"),
    )
    lk.body = [
        WmmaLoad(va, a_load.memref, a_load.idxs, "AOp", (fm, fk)),
        WmmaLoad(vb, b_load.memref, b_load.idxs, "BOp", (fk, fn)),
        WmmaLoad(vc, c_ref, c_load.idxs, "COp", (fm, fn)),
        WmmaMma(vr, va, vb, vc, mnk),
        WmmaStore(vr, c_ref, c_load.idxs, (fm, fn)),
    ]
    mod.meta["wmma"] = True
    mod.meta["wmma_mnk"] = mnk
    return mod
