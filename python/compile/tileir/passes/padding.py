"""§3.3 — Pad shared-memory buffers to avoid bank conflicts.

The leading dimension of each shared buffer is extended by a padding
factor; because the memref layout map absorbs the change, no other IR needs
rewriting — exactly the paper's trick.  The WMMA API requires 128-bit
alignment, so the factor must be a multiple of 8 elements for f16.
"""

from __future__ import annotations

from ..ir import Module, dtype_bytes


class PaddingError(ValueError):
    pass


def pad_shared_buffers(mod: Module, factor: int | None = None) -> Module:
    """Extend the leading dimension of a_smem/b_smem by ``factor`` elements."""
    factor = factor if factor is not None else int(mod.meta.get("pad_factor", 8))
    if not mod.meta.get("shared_mem"):
        raise PaddingError("pad_shared_buffers requires create_shared_buffers first")
    for role in ("a_smem", "b_smem"):
        buf = mod.roles[role]
        align_elems = 16 // dtype_bytes(buf.dtype)  # 128-bit WMMA alignment
        if factor % align_elems != 0:
            raise PaddingError(
                f"padding factor {factor} violates 128-bit alignment for "
                f"{buf.dtype} (must be a multiple of {align_elems})"
            )
        buf.lead_pad = factor
    mod.meta["pad_factor"] = factor
    mod.meta["padded"] = True
    return mod
