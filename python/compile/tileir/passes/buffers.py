"""§3.3 — Create and place shared-memory buffers (affineDataCopyGenerate).

Inserts, at the top of the main k-loop body, copy loop nests that stage the
current ``tbm x tbk`` block of A and ``tbk x tbn`` block of B from global
memory into shared-memory buffers, then rewrites the compute nest's loads
of A and B to read the staged copies with rebased indices.

Following the paper, C is *not* staged through shared memory: each warp
streams its C tile straight into registers (fragment loads), since C is
touched once per thread-block tile.
"""

from __future__ import annotations

from typing import List, Tuple

from ..ir import (
    AffineExpr,
    For,
    Load,
    MemRef,
    Module,
    Op,
    Store,
    fresh_name,
)


class BufferError(ValueError):
    pass


def _copy_nest(
    src: MemRef,
    dst: MemRef,
    row_base: AffineExpr,
    col_base: AffineExpr,
    rows: int,
    cols: int,
    iv_prefix: str,
    role: str,
) -> For:
    """Build ``for r in [row_base, row_base+rows) for c in [...]:
    dst[r - row_base, c - col_base] = src[r, c]``."""
    iv_r = f"%{iv_prefix}r"
    iv_c = f"%{iv_prefix}c"
    er, ec = AffineExpr.var(iv_r), AffineExpr.var(iv_c)
    v = fresh_name("cp")
    inner = For(
        iv=iv_c,
        lb=col_base,
        ub=col_base + cols,
        step=1,
        body=[
            Load(v, src, (er, ec)),
            Store(v, dst, (er - row_base, ec - col_base)),
        ],
        attrs={"role": f"{role}_inner"},
    )
    outer = For(
        iv=iv_r,
        lb=row_base,
        ub=row_base + rows,
        step=1,
        body=[inner],
        attrs={"role": role},
    )
    return outer


def create_shared_buffers(mod: Module) -> Module:
    """Stage A and B thread-block tiles through shared memory."""
    if not mod.meta.get("tiled"):
        raise BufferError("create_shared_buffers requires two_level_tiling first")
    tbm, tbn, tbk = mod.meta["tile_tb"]
    a, b = mod.roles["A"], mod.roles["B"]

    main_k_loops = mod.find_loops(role="main_k")
    if len(main_k_loops) != 1:
        raise BufferError("expected exactly one main k-loop")
    main_k = main_k_loops[0]
    block_i = mod.find_loops(role="block_i")[0]
    block_j = mod.find_loops(role="block_j")[0]

    a_smem = mod.add_memref(
        MemRef("%a_smem", (tbm, tbk), a.dtype, space="shared"), role="a_smem"
    )
    b_smem = mod.add_memref(
        MemRef("%b_smem", (tbk, tbn), b.dtype, space="shared"), role="b_smem"
    )

    ei = AffineExpr.var(block_i.iv)
    ej = AffineExpr.var(block_j.iv)
    ek = AffineExpr.var(main_k.iv)

    # Paper order (Listing 2): B copy first, then A copy.
    copy_b = _copy_nest(b, b_smem, ek, ej, tbk, tbn, "copyb", "copyB")
    copy_a = _copy_nest(a, a_smem, ei, ek, tbm, tbk, "copya", "copyA")
    main_k.body = [copy_b, copy_a] + main_k.body

    # Rewrite compute-nest loads of A/B to the staged buffers, rebasing the
    # block-origin offsets (i for A rows, k for A cols / B rows, j for B cols).
    def rewrite(ops: List[Op]) -> None:
        for op in ops:
            if isinstance(op, For):
                if op.attrs.get("role", "").startswith("copy"):
                    continue
                rewrite(op.body)
            elif isinstance(op, Load):
                if op.memref is a:
                    op.memref = a_smem
                    op.idxs = (op.idxs[0] - ei, op.idxs[1] - ek)
                elif op.memref is b:
                    op.memref = b_smem
                    op.idxs = (op.idxs[0] - ek, op.idxs[1] - ej)

    rewrite(main_k.body)
    mod.meta["shared_mem"] = True
    return mod
