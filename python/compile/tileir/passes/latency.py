"""§3.5 + §3.10 — Global-memory load latency hiding.

Two cooperating transformations, split exactly as in the paper:

``split_main_k_loop`` (§3.5) peels the shared-memory copies for iteration 0
in front of the main k-loop and the compute for the last iteration behind
it, and shifts the in-loop copies one iteration ahead (loading tile
``k + tbk`` while computing on tile ``k``).

``decouple_copy_stores`` (§3.10) completes the optimization: the in-loop
copies are split into a *load phase* (global memory -> a register staging
buffer, issued before the compute) and a *store phase* (staging buffer ->
shared memory, issued after the compute).  Until this step the shifted
copies would clobber the shared-memory tile the compute is still reading —
the paper notes decoupling is "required both for the correctness and
functioning of the optimization"; the pipeline therefore treats §3.5's
output as an intermediate stage and only interpreter-validates after this
pass.
"""

from __future__ import annotations

from typing import List

from ..ir import (
    AffineExpr,
    Barrier,
    For,
    Load,
    MemRef,
    Module,
    Op,
    Store,
    VecLoad,
    VecStore,
    Yield,
    clone_with_fresh_names,
    rename_values,
    subst_exprs,
)


class LatencyError(ValueError):
    pass


def _copy_nests(body: List[Op]) -> List[For]:
    return [
        op
        for op in body
        if isinstance(op, For)
        and op.attrs.get("role", "").startswith("copy")
        and not op.attrs.get("role", "").endswith("_inner")
    ]


def split_main_k_loop(mod: Module) -> Module:
    if not mod.meta.get("hoisted"):
        raise LatencyError("split_main_k_loop requires unroll_and_hoist")
    if not mod.meta.get("shared_mem"):
        raise LatencyError("latency hiding requires shared-memory staging")

    jj = mod.find_loops(role="warp_j")[0]
    k = mod.find_loops(role="main_k")[0]
    kk = mod.find_loops(role="warp_k")[0]
    kdim = mod.meta["K"]
    tbk = mod.meta["tile_tb"][2]
    if kdim // tbk < 2:
        raise LatencyError("need at least two k-tiles to pipeline")

    copies = _copy_nests(k.body)
    if not copies:
        raise LatencyError("no copy nests found in main k-loop")

    # -- prologue: copies for iteration 0, placed right before the k-loop.
    prologue: List[Op] = []
    for nest in copies:
        clone = clone_with_fresh_names([nest], "pro")[0]
        subst_exprs(clone, {k.iv: AffineExpr.cst(0)})
        clone.attrs["stage"] = "prologue"
        prologue.append(clone)

    # -- steady state: shift in-loop copies one tile ahead, shrink bounds.
    for nest in copies:
        subst_exprs(nest, {k.iv: AffineExpr.var(k.iv) + tbk})
        nest.attrs["stage"] = "steady"
    k.ub = AffineExpr.cst(kdim - tbk)

    # -- epilogue: peel the compute (warp k-loop) for the last iteration.
    epi = clone_with_fresh_names([kk], "epi")[0]
    subst_exprs(epi, {k.iv: AffineExpr.cst(kdim - tbk)})
    # The peeled compute consumes the main loop's results as its initial
    # accumulators and produces the values the final stores consume.
    rename_values(
        epi, {arg: res for (arg, _), res in zip(k.iter_args, k.result_names)}
    )
    epi.iter_args = [
        (arg, res) for (arg, _), res in zip(epi.iter_args, k.result_names)
    ]
    final_names = [f"{r}_final" for r in epi.result_names]
    epi.result_names = final_names
    epi.attrs["stage"] = "epilogue"

    # Rewire jj: [C loads, prologue copies, k, epilogue kk, stores(final)].
    # The final stores were consuming the main loop's results; they must now
    # consume the peeled compute's results instead.
    rename_map = dict(zip(k.result_names, final_names))
    idx = jj.body.index(k)
    tail = jj.body[idx + 1 :]
    for op in tail:
        rename_values(op, rename_map)
    jj.body = jj.body[:idx] + prologue + [k, epi] + tail

    mod.meta["latency_split"] = True
    mod.meta["pipeline_stages"] = 2  # single-stage double buffering
    return mod


def decouple_copy_stores(mod: Module) -> Module:
    """Split steady-state copies into load and store phases (§3.10)."""
    if not mod.meta.get("latency_split"):
        raise LatencyError("decouple_copy_stores requires split_main_k_loop")

    k = mod.find_loops(role="main_k")[0]
    copies = [op for op in k.body if isinstance(op, For) and op.attrs.get("stage") == "steady"]
    if not copies:
        raise LatencyError("no steady-state copies to decouple")

    load_phase: List[For] = []
    store_phase: List[For] = []
    for nest in copies:
        role = nest.attrs["role"]  # copyA | copyB
        tile = mod.roles["a_smem" if role == "copyA" else "b_smem"]
        stage_role = "a_stage" if role == "copyA" else "b_stage"
        if stage_role in mod.roles:
            stage = mod.roles[stage_role]
        else:
            stage = mod.add_memref(
                MemRef(f"%{stage_role}", tile.shape, tile.dtype, space="reg"),
                role=stage_role,
            )

        # Locate the (inner) load/store pair of the nest.
        inner = nest
        while inner.body and isinstance(inner.body[0], For):
            inner = inner.body[0]
        loads = [op for op in inner.body if isinstance(op, (Load, VecLoad))]
        stores = [op for op in inner.body if isinstance(op, (Store, VecStore))]
        if len(loads) != 1 or len(stores) != 1:
            raise LatencyError(f"unexpected copy body in {role}")
        smem_idxs = stores[0].idxs

        # Load phase: global -> staging registers (same rebased layout).
        ld = clone_with_fresh_names([nest], "ld")[0]
        ld_inner = ld
        while ld_inner.body and isinstance(ld_inner.body[0], For):
            ld_inner = ld_inner.body[0]
        for op in ld_inner.body:
            if isinstance(op, (Store, VecStore)):
                op.memref = stage
                op.idxs = smem_idxs
        ld.attrs["phase"] = "load"
        load_phase.append(ld)

        # Store phase: staging registers -> shared memory.
        st = clone_with_fresh_names([nest], "st")[0]
        st_inner = st
        while st_inner.body and isinstance(st_inner.body[0], For):
            st_inner = st_inner.body[0]
        for op in st_inner.body:
            if isinstance(op, (Load, VecLoad)):
                op.memref = stage
                op.idxs = smem_idxs
        st.attrs["phase"] = "store"
        store_phase.append(st)

    # Rebuild the steady-state body: loads, compute, (barrier), stores —
    # Listing 6's "global loads for i+1; compute; barrier; smem stores".
    rest = [op for op in k.body if op not in copies]
    yield_ops = [op for op in rest if isinstance(op, Yield)]
    others = [op for op in rest if not isinstance(op, Yield)]
    # Keep the top-of-loop barrier (inserted by §3.6) ahead of the loads.
    top: List[Op] = []
    while others and isinstance(others[0], Barrier):
        top.append(others.pop(0))
    store_barrier: List[Op] = [Barrier()] if mod.meta.get("barriers") else []
    k.body = top + load_phase + others + store_barrier + store_phase + yield_ops

    mod.meta["decoupled"] = True
    return mod
