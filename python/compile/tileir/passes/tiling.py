"""§3.2 — Two-level tiling for locality and parallelism.

First level: thread-block tiles ``(tbm, tbn, tbk)`` — mapped to SMs, backed
by shared memory.  Second level: warp tiles ``(wm, wn, wk)`` — register
reuse and warp-level parallelism.  Implemented with a generic
perfect-nest tiling utility (the MLIR ``loopTiling`` analog) applied twice.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from ..ir import AffineExpr, For, Module, Op, subst_exprs


class TilingError(ValueError):
    pass


def tile_perfect_nest(
    nest: Sequence[For], tile_sizes: Sequence[int], suffix: str
) -> Tuple[List[For], List[For]]:
    """Tile a perfect nest of ``len(tile_sizes)`` loops in place.

    Each loop ``iv`` with step ``s`` becomes an outer loop (same bounds,
    step ``tile * s``) plus an inner loop ``iv+suffix`` over
    ``[0, tile*s)`` with the original step; every use of ``iv`` in the
    enclosed body is rewritten to ``iv + iv_inner``.

    Returns (outer_loops, inner_loops).  The innermost original body is
    re-hung under the innermost new inner loop.
    """
    if len(nest) != len(tile_sizes):
        raise TilingError(f"need {len(nest)} tile sizes, got {len(tile_sizes)}")
    for loop, t in zip(nest, tile_sizes):
        span = loop.ub.const - loop.lb.const
        if not loop.lb.is_const() or not loop.ub.is_const():
            raise TilingError(f"loop {loop.iv} has non-constant bounds")
        if t % loop.step != 0:
            raise TilingError(f"tile {t} not a multiple of step {loop.step}")
        if span % t != 0:
            raise TilingError(
                f"loop {loop.iv} span {span} not a multiple of tile {t}"
            )

    # Innermost body to re-hang below the new inner loops.
    inner_body: List[Op] = nest[-1].body

    mapping: Dict[str, AffineExpr] = {}
    inner_loops: List[For] = []
    for loop, t in zip(nest, tile_sizes):
        inner_iv = f"{loop.iv}{suffix}"
        mapping[loop.iv] = AffineExpr.var(loop.iv) + AffineExpr.var(inner_iv)
        inner_loops.append(
            For(
                iv=inner_iv,
                lb=AffineExpr.cst(0),
                ub=AffineExpr.cst(t),
                step=loop.step,
                body=[],
                attrs=dict(loop.attrs),
            )
        )
        loop.step = t

    # Rewrite every index expression in the original body.
    for op in inner_body:
        subst_exprs(op, mapping)

    # Chain: outer nest -> inner loops -> original body.
    for outer, inner in zip(inner_loops[:-1], inner_loops[1:]):
        outer.body = [inner]
    inner_loops[-1].body = inner_body
    nest[-1].body = [inner_loops[0]]
    return list(nest), inner_loops


def two_level_tiling(mod: Module) -> Module:
    """Apply thread-block then warp tiling to the naive 3-loop matmul."""
    tb = mod.meta["tile_tb"]  # (tbm, tbn, tbk)
    warp = mod.meta["tile_warp"]  # (wm, wn, wk)
    tbm, tbn, tbk = tb
    wm, wn, wk = warp
    if any(t % w != 0 for t, w in zip(tb, warp)):
        raise TilingError(f"thread-block tile {tb} not a multiple of warp tile {warp}")

    nest = mod.loop_nest()
    if len(nest) != 3:
        raise TilingError(f"expected naive 3-loop nest, found depth {len(nest)}")
    i, j, k = nest

    # Level 1: thread-block tiles.  The k-loop at step tbk becomes the
    # "main k-loop" of the paper.
    _, inner1 = tile_perfect_nest([i, j, k], [tbm, tbn, tbk], suffix="i")
    ii, jj, kk = inner1

    # Level 2: warp tiles on the intra-block loops.
    _, inner2 = tile_perfect_nest([ii, jj, kk], [wm, wn, wk], suffix="i")

    i.attrs["role"] = "block_i"
    j.attrs["role"] = "block_j"
    k.attrs["role"] = "main_k"
    ii.attrs["role"] = "warp_i"
    jj.attrs["role"] = "warp_j"
    kk.attrs["role"] = "warp_k"
    for frag, role in zip(inner2, ("frag_i", "frag_j", "frag_k")):
        frag.attrs["role"] = role

    mod.meta["tiled"] = True
    return mod
