"""The pipeline passes of the paper's §3, one module per pass.

Order (mirrors §3.2-§3.10):

1.  ``tiling.two_level_tiling``            — §3.2
2.  ``buffers.create_shared_buffers``      — §3.3 (affineDataCopyGenerate)
3.  ``padding.pad_shared_buffers``         — §3.3 (bank-conflict padding)
4.  ``wmma.generate_wmma_ops``             — §3.4
5.  ``permute.permute_for_gpu_hierarchy``  — §3.4 (loop permutations)
6.  ``unroll_hoist.unroll_and_hoist``      — §3.4 (unroll, CSE, iter_args)
7.  ``latency.split_main_k_loop``          — §3.5 (peel copy/compute)
8.  ``barriers.insert_barriers``           — §3.6
9.  ``vectorize.vectorize_copies``         — §3.7
10. ``latency.decouple_copy_stores``       — §3.10 (complete latency hiding)
11. ``parallelize.extract_and_map_parallel`` — §3.8/§3.9
"""

from .tiling import two_level_tiling, tile_perfect_nest
from .buffers import create_shared_buffers
from .padding import pad_shared_buffers
from .wmma import generate_wmma_ops
from .permute import permute_for_gpu_hierarchy
from .unroll_hoist import unroll_and_hoist, fully_unroll
from .latency import split_main_k_loop, decouple_copy_stores
from .barriers import insert_barriers
from .vectorize import vectorize_copies
from .parallelize import extract_and_map_parallel

__all__ = [
    "two_level_tiling",
    "tile_perfect_nest",
    "create_shared_buffers",
    "pad_shared_buffers",
    "generate_wmma_ops",
    "permute_for_gpu_hierarchy",
    "unroll_and_hoist",
    "fully_unroll",
    "split_main_k_loop",
    "decouple_copy_stores",
    "insert_barriers",
    "vectorize_copies",
    "extract_and_map_parallel",
]
