"""§3.4 — Loop permutations for the GPU compute hierarchy.

Two permutations, exactly as the paper describes:

* the outer six loops go from ``(i, j, k, ii, jj, kk)`` to
  ``(i, j, ii, jj, k, kk)`` so blocks/warps become the outer dimensions and
  C's fragment load/stores become hoistable out of the k-loops;
* the fragment loops go from ``(iii, jjj, kkk)`` to ``(kkk, iii, jjj)`` so
  the warp-level MMA is an outer product over the fragment grid, enhancing
  ILP (per Bhaskaracharya et al.).

The copy loop nests (if shared-memory staging is enabled) stay attached to
the main k-loop body, before the warp k-loop.
"""

from __future__ import annotations

from typing import List

from ..ir import For, Module, Op


class PermuteError(ValueError):
    pass


def _single(mod: Module, role: str) -> For:
    loops = mod.find_loops(role=role)
    if len(loops) != 1:
        raise PermuteError(f"expected exactly one loop with role={role}")
    return loops[0]


def permute_for_gpu_hierarchy(mod: Module) -> Module:
    if not mod.meta.get("wmma"):
        raise PermuteError("permute_for_gpu_hierarchy requires generate_wmma_ops")

    i = _single(mod, "block_i")
    j = _single(mod, "block_j")
    k = _single(mod, "main_k")
    ii = _single(mod, "warp_i")
    jj = _single(mod, "warp_j")
    kk = _single(mod, "warp_k")
    iii = _single(mod, "frag_i")
    jjj = _single(mod, "frag_j")
    kkk = _single(mod, "frag_k")

    # Copy nests currently live at the head of the main k-loop body.
    copies: List[Op] = [
        op
        for op in k.body
        if isinstance(op, For) and op.attrs.get("role", "").startswith("copy")
    ]

    # Fragment permutation: (iii, jjj, kkk) -> (kkk, iii, jjj).
    frag_body = kkk.body  # the WMMA op sequence
    kkk.body = [iii]
    iii.body = [jjj]
    jjj.body = frag_body

    # Outer permutation: (i, j, k, ii, jj, kk) -> (i, j, ii, jj, k, kk).
    kk.body = [kkk]
    k.body = copies + [kk]
    jj.body = [k]
    ii.body = [jj]
    j.body = [ii]
    # i.body already [j]

    mod.meta["permuted"] = True
    return mod
