"""§3.8 + §3.9 — Extract parallel loops and map them to the GPU hierarchy.

``isLoopParallel``/``affineParallelize`` analog: a loop is parallel when it
carries no cross-iteration dependence.  The check used here is the
conservative memory-based one sufficient for this pipeline's loop
structures: a loop with ``iter_args`` is sequential; otherwise every store
in its body must be to an address that varies with the loop IV (distinct
iterations touch distinct elements).

The mapping step then assigns the two outermost parallel loops to the
thread-block grid and the next two to warps, recording launch dimensions in
module meta (the ``gpu.launch`` of §3.9; our Pallas emitter consumes the
same mapping as its grid).
"""

from __future__ import annotations

from typing import List

from ..ir import For, Module, Op, Store, VecStore, WmmaStore


class ParallelizeError(ValueError):
    pass


def is_loop_parallel(loop: For) -> bool:
    """Memory-based parallelism check (conservative)."""
    if loop.iter_args:
        return False

    stores: List[Op] = []

    def collect(ops: List[Op]) -> None:
        for op in ops:
            if isinstance(op, (Store, VecStore, WmmaStore)):
                stores.append(op)
            elif isinstance(op, For):
                collect(op.body)

    collect(loop.body)
    for st in stores:
        if st.memref.space != "global":  # type: ignore[union-attr]
            # Shared/register buffers are per-block (resp. cooperative)
            # storage on the GPU: privatized by the mapping, so they do not
            # inhibit block/warp parallelism — the MLIR GPU dialect treats
            # workgroup memory the same way.
            continue
        idxs = st.idxs  # type: ignore[union-attr]
        if all(e.coeff(loop.iv) == 0 for e in idxs):
            # Same element written by every iteration -> loop-carried.
            return False
    return True


def extract_and_map_parallel(mod: Module) -> Module:
    block_i = mod.find_loops(role="block_i")[0]
    block_j = mod.find_loops(role="block_j")[0]

    mapping = [("block_i", "block_y"), ("block_j", "block_x")]
    if mod.meta.get("tiled"):
        mapping += [("warp_i", "warp_y"), ("warp_j", "warp_x")]

    for role, target in mapping:
        loops = mod.find_loops(role=role)
        if len(loops) != 1:
            raise ParallelizeError(f"expected exactly one {role} loop")
        loop = loops[0]
        if not is_loop_parallel(loop):
            raise ParallelizeError(f"{role} loop is not parallel; cannot map")
        loop.attrs["parallel"] = target

    # Launch geometry (the gpu.launch equivalent).
    m, n = mod.meta["M"], mod.meta["N"]
    if mod.meta.get("tiled"):
        tbm, tbn, _ = mod.meta["tile_tb"]
        wm, wn, _ = mod.meta["tile_warp"]
        grid = (m // tbm, n // tbn)
        warps = (tbm // wm, tbn // wn)
    else:
        grid = (m, n)
        warps = (1, 1)
    mod.meta["grid"] = grid
    mod.meta["warps_per_block"] = warps
    mod.meta["threads_per_block"] = warps[0] * warps[1] * 32
    mod.meta["parallelized"] = True
    return mod
