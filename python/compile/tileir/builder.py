"""Builders for the pipeline's starting-point IR.

The paper's §3.1: the entry is a naive three-loop affine matmul (Listing 1),
assumed to come from lowering ``lmhlo.dot`` / ``linalg.matmul``.  We provide
that plus the fused-epilogue variant used for the operator-fusion
experiments (Table 1 column 4).
"""

from __future__ import annotations

from typing import Optional, Tuple

from .ir import (
    F16,
    F32,
    AddF,
    AffineExpr,
    For,
    FpExt,
    Load,
    Module,
    MemRef,
    MulF,
    Store,
    fresh_name,
)


def build_naive_matmul(
    m: int,
    n: int,
    k: int,
    dtype_in: str = F16,
    dtype_acc: str = F32,
    name: Optional[str] = None,
) -> Module:
    """Listing 1: ``C[i,j] += ext(A[i,k]) * ext(B[k,j])`` over an MxNxK nest.

    ``dtype_in == f16, dtype_acc == f32`` is the paper's mixed-precision
    configuration; ``f16/f16`` is the half-precision one (§4.2);
    ``f32/f32`` models the TF32 path.
    """
    name = name or f"matmul_{m}x{n}x{k}_{dtype_in}_{dtype_acc}"
    mod = Module(name=name)
    a = mod.add_memref(MemRef("%A", (m, k), dtype_in), role="A")
    b = mod.add_memref(MemRef("%B", (k, n), dtype_in), role="B")
    c = mod.add_memref(MemRef("%C", (m, n), dtype_acc), role="C")

    iv_i, iv_j, iv_k = "%i", "%j", "%k"
    ei = AffineExpr.var(iv_i)
    ej = AffineExpr.var(iv_j)
    ek = AffineExpr.var(iv_k)

    va = fresh_name("a")
    vb = fresh_name("b")
    vc = fresh_name("c")
    body = [
        Load(va, a, (ei, ek)),
        Load(vb, b, (ek, ej)),
        Load(vc, c, (ei, ej)),
    ]
    if dtype_in != dtype_acc:
        vaq, vbq = fresh_name("aq"), fresh_name("bq")
        body += [
            FpExt(vaq, va, dtype_in, dtype_acc),
            FpExt(vbq, vb, dtype_in, dtype_acc),
        ]
    else:
        vaq, vbq = va, vb
    vq, vco = fresh_name("q"), fresh_name("co")
    body += [
        MulF(vq, vaq, vbq, dtype_acc),
        AddF(vco, vc, vq, dtype_acc),
        Store(vco, c, (ei, ej)),
    ]

    loop_k = For(iv_k, AffineExpr.cst(0), AffineExpr.cst(k), 1, body,
                 attrs={"role": "main_k"})
    loop_j = For(iv_j, AffineExpr.cst(0), AffineExpr.cst(n), 1, [loop_k],
                 attrs={"role": "block_j"})
    loop_i = For(iv_i, AffineExpr.cst(0), AffineExpr.cst(m), 1, [loop_j],
                 attrs={"role": "block_i"})
    mod.body = [loop_i]
    mod.meta.update(
        {
            "M": m,
            "N": n,
            "K": k,
            "dtype_in": dtype_in,
            "dtype_acc": dtype_acc,
            "epilogue": "none",
        }
    )
    return mod


def build_fused_matmul_bias_relu(
    m: int,
    n: int,
    k: int,
    dtype_in: str = F16,
    dtype_acc: str = F32,
    relu: bool = True,
) -> Module:
    """Matmul with a fused bias-add (+ optional ReLU) epilogue.

    The epilogue is recorded in module meta; the pipeline treats the matmul
    loop nest identically and the emitter applies the epilogue on the final
    accumulator tile — the fusion style of Bhaskaracharya et al. that the
    paper cites as the motivation for IR-based codegen.
    """
    mod = build_naive_matmul(m, n, k, dtype_in, dtype_acc)
    mod.name += "_bias" + ("_relu" if relu else "")
    mod.add_memref(MemRef("%bias", (1, n), dtype_acc), role="bias")
    mod.meta["epilogue"] = "bias_relu" if relu else "bias"
    return mod
