"""tileir — the paper's MLIR lowering pipeline, reimplemented.

Public surface:

* :mod:`tileir.ir` — the IR (ops, memrefs, affine expressions);
* :func:`tileir.builder.build_naive_matmul` — the §3.1 starting point;
* :mod:`tileir.passes` — the §3.2-§3.10 transformation passes;
* :class:`tileir.pipeline.PipelineConfig` / :func:`run_pipeline` — the
  pass manager and ablation toggles;
* :func:`tileir.schedule.extract_schedule` — the backend contract;
* :func:`tileir.printer.print_module` — MLIR-style listings;
* :class:`tileir.interp.Interpreter` — the semantic oracle.
"""

from .builder import build_fused_matmul_bias_relu, build_naive_matmul
from .interp import Interpreter, run_matmul_module
from .pipeline import OPT_ORDER, PipelineConfig, PipelineError, PipelineResult, run_pipeline
from .printer import print_module
from .schedule import Schedule, ScheduleError, extract_schedule

__all__ = [
    "build_naive_matmul",
    "build_fused_matmul_bias_relu",
    "Interpreter",
    "run_matmul_module",
    "OPT_ORDER",
    "PipelineConfig",
    "PipelineError",
    "PipelineResult",
    "run_pipeline",
    "print_module",
    "Schedule",
    "ScheduleError",
    "extract_schedule",
]
