"""AOT lowering driver: jax graphs -> artifacts/*.hlo.txt + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly.  See /opt/xla-example and
DESIGN.md.

The manifest records, for every artifact: the file, the input/output
shapes and dtypes, the kind (generated | baseline | fused | unfused |
hand | transformer), and — for generated kernels — the full Schedule the
Rust simulator and autotuner consume.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile
target).  ``--quick`` lowers a reduced variant set for fast iteration.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import asdict
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import generate_matmul_with_schedule, hand_optimized_matmul, jdtype
from .model import (
    matmul_baseline,
    transformer_layer,
    transformer_layer_inputs,
    unfused_epilogue,
)
from .tileir import PipelineConfig


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s: jax.ShapeDtypeStruct) -> Dict:
    name = {"float16": "f16", "bfloat16": "bf16", "float32": "f32"}[str(s.dtype)]
    return {"shape": list(s.shape), "dtype": name}


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: List[Dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def lower(
        self,
        name: str,
        fn: Callable,
        arg_shapes: Sequence[jax.ShapeDtypeStruct],
        kind: str,
        schedule: Optional[Dict] = None,
        extra: Optional[Dict] = None,
    ) -> None:
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*arg_shapes)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            _shape_entry(o) for o in jax.eval_shape(fn, *arg_shapes)
        ]
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "kind": kind,
            "inputs": [_shape_entry(s) for s in arg_shapes],
            "outputs": out_shapes,
        }
        if schedule is not None:
            entry["schedule"] = schedule
        if extra:
            entry.update(extra)
        self.entries.append(entry)
        print(f"  wrote {path} ({len(text)} chars)")

    def finish(self) -> None:
        manifest = os.path.join(self.out_dir, "manifest.json")
        with open(manifest, "w") as f:
            json.dump({"version": 1, "artifacts": self.entries}, f, indent=1)
        print(f"manifest: {manifest} ({len(self.entries)} artifacts)")


def _mm_shapes(m, n, k, dtype_in, dtype_acc, bias=False):
    """External I/O is always f32: the xla crate's F16 is a dummy type with
    no literal constructors, so precision casts live *inside* the graph
    (exactly like cuBLAS's internal TF32/f16 conversion modes)."""
    f32 = jnp.float32
    shapes = [
        jax.ShapeDtypeStruct((m, k), f32),
        jax.ShapeDtypeStruct((k, n), f32),
        jax.ShapeDtypeStruct((m, n), f32),
    ]
    if bias:
        shapes.append(jax.ShapeDtypeStruct((n,), f32))
    return shapes


def as_f32_io(fn):
    """Wrap a graph so its outputs are f32 at the artifact boundary."""

    def wrapped(*args):
        return tuple(o.astype(jnp.float32) for o in fn(*args))

    return wrapped


def _emit_generated(w: ArtifactWriter, config: PipelineConfig, kind="generated"):
    kernel, sched = generate_matmul_with_schedule(config)
    bias = config.epilogue != "none"

    if bias:

        def fn(a, b, c, bias_vec):
            return (kernel(a, b, c, bias_vec),)

    else:

        def fn(a, b, c):
            return (kernel(a, b, c),)

    w.lower(
        sched.name,
        as_f32_io(fn),
        _mm_shapes(config.m, config.n, config.k, config.dtype_in,
                   config.dtype_acc, bias),
        kind=kind,
        schedule=sched.to_json_dict(),
    )


def _emit_baseline(w: ArtifactWriter, m, n, k, dtype_in="f16", dtype_acc="f32"):
    fn = as_f32_io(matmul_baseline(m, n, k, dtype_in, dtype_acc))
    w.lower(
        f"baseline_m{m}n{n}k{k}_{dtype_in}_{dtype_acc}",
        fn,
        _mm_shapes(m, n, k, dtype_in, dtype_acc),
        kind="baseline",
        extra={"m": m, "n": n, "k": k, "dtype_in": dtype_in, "dtype_acc": dtype_acc},
    )


# Tile candidates per problem size, mirroring §4.1's observation that small
# problems prefer small (occupancy-friendly) tiles and large problems big
# (reuse-friendly) tiles.  The Rust autotuner picks among these.
def tile_candidates(size: int):
    cands = [((64, 64, 64), (32, 32, 32))]
    if size >= 512:
        cands.append(((128, 128, 64), (64, 32, 32)))
    return cands


def build_all(out_dir: str, quick: bool = False) -> None:
    w = ArtifactWriter(out_dir)

    sweep_sizes = [256] if quick else [256, 512, 1024]
    print("== generated + baseline matmuls (fig2 real-execution subset) ==")
    for size in sweep_sizes:
        for tb, warp in tile_candidates(size):
            cfg = PipelineConfig(m=size, n=size, k=size, tile_tb=tb, tile_warp=warp)
            _emit_generated(w, cfg)
        _emit_baseline(w, size, size, size)

    print("== half-precision variants (fig4 real-execution subset) ==")
    for size in [256] if quick else [256, 512]:
        tb, warp = tile_candidates(size)[0]
        cfg = PipelineConfig(
            m=size, n=size, k=size, dtype_acc="f16", tile_tb=tb, tile_warp=warp
        )
        _emit_generated(w, cfg)
        _emit_baseline(w, size, size, size, dtype_acc="f16")

    print("== ablation ladder (fig3 real-execution check) ==")
    abl_size = 256
    for level in range(8):
        cfg = PipelineConfig.opt_level(
            level, m=abl_size, n=abl_size, k=abl_size,
            tile_tb=(64, 64, 64), tile_warp=(32, 32, 32),
        )
        _emit_generated(w, cfg, kind="ablation")

    print("== operator fusion (table1) ==")
    fsize = 256 if quick else 512
    fused_cfg = PipelineConfig(
        m=fsize, n=fsize, k=fsize, epilogue="bias_relu",
        tile_tb=(64, 64, 64), tile_warp=(32, 32, 32),
    )
    _emit_generated(w, fused_cfg, kind="fused")
    unfused_cfg = PipelineConfig(
        m=fsize, n=fsize, k=fsize,
        tile_tb=(64, 64, 64), tile_warp=(32, 32, 32),
    )
    fn = as_f32_io(unfused_epilogue(unfused_cfg))
    w.lower(
        f"unfused_m{fsize}n{fsize}k{fsize}_f16_f32",
        fn,
        _mm_shapes(fsize, fsize, fsize, "f16", "f32", bias=True),
        kind="unfused",
        extra={"m": fsize, "n": fsize, "k": fsize,
               "dtype_in": "f16", "dtype_acc": "f32"},
    )

    print("== hand-optimized kernel (table1 'assembly' row) ==")
    hsize = 256 if quick else 512
    hand = hand_optimized_matmul(hsize, hsize, hsize, tile=(64, 64, 64))

    def hand_fn(a, b, c):
        return (hand(a, b, c).astype(jnp.float32),)

    w.lower(
        f"hand_m{hsize}n{hsize}k{hsize}_f16_f32",
        hand_fn,
        _mm_shapes(hsize, hsize, hsize, "f16", "f32"),
        kind="hand",
        extra={"m": hsize, "n": hsize, "k": hsize,
               "dtype_in": "f16", "dtype_acc": "f32"},
    )

    print("== end-to-end transformer layer ==")
    dims = dict(seq=128, d_model=256, d_ff=512)
    layer = transformer_layer(
        **dims, tile_tb=(64, 64, 64), tile_warp=(32, 32, 32)
    )
    w.lower(
        "transformer_layer_s{seq}d{d_model}f{d_ff}".format(**dims),
        as_f32_io(layer),
        transformer_layer_inputs(**dims),
        kind="transformer",
        extra=dims,
    )

    w.finish()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="reduced variant set")
    args = ap.parse_args()
    build_all(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
