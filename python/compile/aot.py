"""AOT lowering driver: jax graphs -> artifacts/*.tprog.json + manifest.json.

The interchange format with the Rust runtime is a *tensor-program
descriptor* per artifact (``<name>.tprog.json``): a small JSON document
naming the program's executable semantics (GEMM shape, precision modes,
fused epilogue; or the transformer block's dimensions).  The offline
Rust toolchain has no PJRT bindings, so its runtime executes these
descriptors directly (``rust/src/runtime/exec.rs``) with the same
precision structure the jax graphs encode (f32 at the boundary, dtype
casts inside).  See DESIGN.md §3.

Every descriptor is cross-checked at write time against the actual jax
graph via ``jax.eval_shape`` — a program whose declared I/O contract
diverges from the traced computation fails here, and the Rust loader
re-checks the same contract against the manifest at load time.

HLO text export (``to_hlo_text``) is kept for provenance and for
PJRT-capable environments; pass ``--hlo`` to emit ``<name>.hlo.txt``
next to each descriptor.

The manifest records, for every artifact: the program file, the
input/output shapes and dtypes, the kind (generated | baseline |
ablation | fused | unfused | hand | transformer), and — for generated
kernels — the full Schedule the Rust simulator and autotuner consume.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile
target).  ``--quick`` lowers a reduced variant set for fast iteration.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .kernels import generate_matmul_with_schedule, hand_optimized_matmul
from .model import (
    matmul_baseline,
    transformer_layer,
    transformer_layer_inputs,
    unfused_epilogue,
)
from .tileir import PipelineConfig

TPROG_FORMAT = "mlir-gemm-tprog-v1"


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (PJRT-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s: jax.ShapeDtypeStruct) -> Dict:
    name = {"float16": "f16", "bfloat16": "bf16", "float32": "f32"}[str(s.dtype)]
    return {"shape": list(s.shape), "dtype": name}


def gemm_program(
    m: int,
    n: int,
    k: int,
    dtype_in: str = "f16",
    dtype_acc: str = "f32",
    epilogue: str = "none",
    fused: bool = True,
) -> Dict:
    """Descriptor for a C = A@B + C (+ epilogue) program."""
    return {
        "type": "gemm",
        "m": m,
        "n": n,
        "k": k,
        "dtype_in": dtype_in,
        "dtype_acc": dtype_acc,
        "epilogue": epilogue,
        "fused": fused,
    }


def transformer_program(
    seq: int, d_model: int, d_ff: int, n_heads: int = 4, dtype_in: str = "f16"
) -> Dict:
    return {
        "type": "transformer",
        "seq": seq,
        "d_model": d_model,
        "d_ff": d_ff,
        "n_heads": n_heads,
        "dtype_in": dtype_in,
    }


def program_input_shapes(program: Dict) -> List[List[int]]:
    """The I/O contract implied by a descriptor (mirror of
    ``Program::input_shapes`` in rust/src/runtime/exec.rs)."""
    if program["type"] == "gemm":
        m, n, k = program["m"], program["n"], program["k"]
        shapes = [[m, k], [k, n], [m, n]]
        if program["epilogue"] != "none":
            shapes.append([n])
        return shapes
    if program["type"] == "transformer":
        s, dm, df = program["seq"], program["d_model"], program["d_ff"]
        return [[s, dm], [dm, 3 * dm], [dm, dm], [dm, df], [df], [df, dm], [dm]]
    raise ValueError(f"unknown program type {program['type']!r}")


def program_output_shapes(program: Dict) -> List[List[int]]:
    if program["type"] == "gemm":
        return [[program["m"], program["n"]]]
    if program["type"] == "transformer":
        return [[program["seq"], program["d_model"]]]
    raise ValueError(f"unknown program type {program['type']!r}")


def _mm_shapes(m, n, k, bias=False):
    """External I/O is always f32: precision casts live *inside* the
    graphs (exactly like cuBLAS's internal TF32/f16 conversion modes),
    and the Rust executor reproduces them from the descriptor."""
    f32 = jnp.float32
    shapes = [
        jax.ShapeDtypeStruct((m, k), f32),
        jax.ShapeDtypeStruct((k, n), f32),
        jax.ShapeDtypeStruct((m, n), f32),
    ]
    if bias:
        shapes.append(jax.ShapeDtypeStruct((n,), f32))
    return shapes


def as_f32_io(fn):
    """Wrap a graph so its outputs are f32 at the artifact boundary."""

    def wrapped(*args):
        return tuple(o.astype(jnp.float32) for o in fn(*args))

    return wrapped


class ArtifactWriter:
    def __init__(self, out_dir: str, emit_hlo: bool = False):
        self.out_dir = out_dir
        self.emit_hlo = emit_hlo
        self.entries: List[Dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def lower(
        self,
        name: str,
        fn: Callable,
        arg_shapes: Sequence[jax.ShapeDtypeStruct],
        kind: str,
        program: Dict,
        schedule: Optional[Dict] = None,
        extra: Optional[Dict] = None,
    ) -> None:
        # Manifest names are the runtime's routing keys; a duplicate
        # would overwrite the first artifact's descriptor file and leave
        # two manifest entries shadowing each other (the Rust loader
        # rejects such manifests outright).  Fail before writing.
        if any(e["name"] == name for e in self.entries):
            raise ValueError(
                f"duplicate artifact name {name!r}: every artifact must be "
                "uniquely addressable"
            )

        out_shapes = [_shape_entry(o) for o in jax.eval_shape(fn, *arg_shapes)]
        in_shapes = [_shape_entry(s) for s in arg_shapes]

        # The descriptor must agree with the traced graph: this is the
        # write-time half of the contract the Rust loader re-checks.
        got_in = [e["shape"] for e in in_shapes]
        got_out = [e["shape"] for e in out_shapes]
        if got_in != program_input_shapes(program):
            raise ValueError(
                f"{name}: graph inputs {got_in} disagree with program "
                f"contract {program_input_shapes(program)}"
            )
        if got_out != program_output_shapes(program):
            raise ValueError(
                f"{name}: graph outputs {got_out} disagree with program "
                f"contract {program_output_shapes(program)}"
            )

        file_name = f"{name}.tprog.json"
        path = os.path.join(self.out_dir, file_name)
        with open(path, "w") as f:
            json.dump(
                {"format": TPROG_FORMAT, "name": name, "program": program},
                f,
                indent=1,
            )

        entry = {
            "name": name,
            "file": file_name,
            "kind": kind,
            "inputs": in_shapes,
            "outputs": out_shapes,
        }
        if self.emit_hlo:
            hlo_name = f"{name}.hlo.txt"
            text = to_hlo_text(jax.jit(fn).lower(*arg_shapes))
            with open(os.path.join(self.out_dir, hlo_name), "w") as f:
                f.write(text)
            entry["hlo_file"] = hlo_name
        if schedule is not None:
            entry["schedule"] = schedule
        if extra:
            entry.update(extra)
        self.entries.append(entry)
        print(f"  wrote {path}")

    def finish(self) -> None:
        manifest = os.path.join(self.out_dir, "manifest.json")
        with open(manifest, "w") as f:
            json.dump({"version": 1, "artifacts": self.entries}, f, indent=1)
        print(f"manifest: {manifest} ({len(self.entries)} artifacts)")


def _emit_generated(
    w: ArtifactWriter, config: PipelineConfig, kind="generated", name_suffix=""
):
    kernel, sched = generate_matmul_with_schedule(config)
    bias = config.epilogue != "none"

    if bias:

        def fn(a, b, c, bias_vec):
            return (kernel(a, b, c, bias_vec),)

    else:

        def fn(a, b, c):
            return (kernel(a, b, c),)

    w.lower(
        sched.name + name_suffix,
        as_f32_io(fn),
        _mm_shapes(config.m, config.n, config.k, bias),
        kind=kind,
        program=gemm_program(
            config.m,
            config.n,
            config.k,
            config.dtype_in,
            config.dtype_acc,
            config.epilogue,
        ),
        schedule=sched.to_json_dict(),
    )


def _emit_baseline(w: ArtifactWriter, m, n, k, dtype_in="f16", dtype_acc="f32"):
    fn = as_f32_io(matmul_baseline(m, n, k, dtype_in, dtype_acc))
    w.lower(
        f"baseline_m{m}n{n}k{k}_{dtype_in}_{dtype_acc}",
        fn,
        _mm_shapes(m, n, k),
        kind="baseline",
        program=gemm_program(m, n, k, dtype_in, dtype_acc),
        extra={"m": m, "n": n, "k": k, "dtype_in": dtype_in, "dtype_acc": dtype_acc},
    )


# Tile candidates per problem size, mirroring §4.1's observation that small
# problems prefer small (occupancy-friendly) tiles and large problems big
# (reuse-friendly) tiles.  The Rust autotuner picks among these.
def tile_candidates(size: int):
    cands = [((64, 64, 64), (32, 32, 32))]
    if size >= 512:
        cands.append(((128, 128, 64), (64, 32, 32)))
    return cands


def build_all(out_dir: str, quick: bool = False, emit_hlo: bool = False) -> None:
    w = ArtifactWriter(out_dir, emit_hlo=emit_hlo)

    sweep_sizes = [256] if quick else [256, 512, 1024]
    print("== generated + baseline matmuls (fig2 real-execution subset) ==")
    for size in sweep_sizes:
        for tb, warp in tile_candidates(size):
            cfg = PipelineConfig(m=size, n=size, k=size, tile_tb=tb, tile_warp=warp)
            _emit_generated(w, cfg)
        _emit_baseline(w, size, size, size)

    print("== half-precision variants (fig4 real-execution subset) ==")
    for size in [256] if quick else [256, 512]:
        tb, warp = tile_candidates(size)[0]
        cfg = PipelineConfig(
            m=size, n=size, k=size, dtype_acc="f16", tile_tb=tb, tile_warp=warp
        )
        _emit_generated(w, cfg)
        _emit_baseline(w, size, size, size, dtype_acc="f16")

    print("== ablation ladder (fig3 real-execution check) ==")
    abl_size = 256
    for level in range(8):
        cfg = PipelineConfig.opt_level(
            level, m=abl_size, n=abl_size, k=abl_size,
            tile_tb=(64, 64, 64), tile_warp=(32, 32, 32),
        )
        # Suffix every rung: the full-opt rung (level 7) has the same
        # PipelineConfig — and therefore the same variant name — as the
        # fig2 generated kernel at this size/tiling, and manifest names
        # must stay unique (ArtifactWriter.lower and the Rust loader
        # both reject collisions).
        _emit_generated(w, cfg, kind="ablation", name_suffix=f"__abl{level}")

    print("== operator fusion (table1) ==")
    fsize = 256 if quick else 512
    fused_cfg = PipelineConfig(
        m=fsize, n=fsize, k=fsize, epilogue="bias_relu",
        tile_tb=(64, 64, 64), tile_warp=(32, 32, 32),
    )
    _emit_generated(w, fused_cfg, kind="fused")
    unfused_cfg = PipelineConfig(
        m=fsize, n=fsize, k=fsize,
        tile_tb=(64, 64, 64), tile_warp=(32, 32, 32),
    )
    fn = as_f32_io(unfused_epilogue(unfused_cfg))
    w.lower(
        f"unfused_m{fsize}n{fsize}k{fsize}_f16_f32",
        fn,
        _mm_shapes(fsize, fsize, fsize, bias=True),
        kind="unfused",
        program=gemm_program(
            fsize, fsize, fsize, "f16", "f32", epilogue="bias_relu", fused=False
        ),
        extra={"m": fsize, "n": fsize, "k": fsize,
               "dtype_in": "f16", "dtype_acc": "f32"},
    )

    print("== hand-optimized kernel (table1 'assembly' row) ==")
    hsize = 256 if quick else 512
    hand = hand_optimized_matmul(hsize, hsize, hsize, tile=(64, 64, 64))

    def hand_fn(a, b, c):
        return (hand(a, b, c).astype(jnp.float32),)

    w.lower(
        f"hand_m{hsize}n{hsize}k{hsize}_f16_f32",
        hand_fn,
        _mm_shapes(hsize, hsize, hsize),
        kind="hand",
        program=gemm_program(hsize, hsize, hsize, "f16", "f32"),
        extra={"m": hsize, "n": hsize, "k": hsize,
               "dtype_in": "f16", "dtype_acc": "f32"},
    )

    print("== end-to-end transformer layer ==")
    dims = dict(seq=128, d_model=256, d_ff=512)
    layer = transformer_layer(
        **dims, tile_tb=(64, 64, 64), tile_warp=(32, 32, 32)
    )
    w.lower(
        "transformer_layer_s{seq}d{d_model}f{d_ff}".format(**dims),
        as_f32_io(layer),
        transformer_layer_inputs(**dims),
        kind="transformer",
        program=transformer_program(**dims),
        extra=dims,
    )

    w.finish()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="reduced variant set")
    ap.add_argument(
        "--hlo", action="store_true",
        help="also emit HLO text next to each program descriptor",
    )
    args = ap.parse_args()
    build_all(args.out_dir, quick=args.quick, emit_hlo=args.hlo)


if __name__ == "__main__":
    main()
