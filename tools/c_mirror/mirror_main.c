/* Driver for the C mirror: validates the nanokernel numerics claims,
 * then measures the exec_kernel policy ladder for BENCH_exec_kernel.json.
 *
 * Checks (all mirroring Rust test assertions):
 *   1. tiled scalar == naive, bitwise (packed-path mirror fidelity);
 *   2. banded == single-thread, bitwise, scalar AND avx2 engines;
 *   3. portable nanokernel == naive, bitwise (plain mul+add, same order);
 *   4. avx2 nanokernel passes verify_fma_relaxed on the ragged shape
 *      family + the bench sizes; max observed ULP reported;
 *   5. avx512 nanokernel ditto, runtime-gated on mirror_have_avx512()
 *      (skipped with an explicit line on hosts without avx512f).
 *
 * Usage: mirror [--verify-only]
 */
#include "mirror.h"

#include <inttypes.h>
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

/* util/prng.rs: splitmix64 + Box-Muller-free normal approx is not
 * needed here — any deterministic distribution works for the checks,
 * and the timings are data-independent.  Keep it simple and portable. */
static uint64_t rng_state;
static uint64_t next_u64(void) {
    uint64_t z = (rng_state += 0x9E3779B97F4A7C15ull);
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ull;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBull;
    return z ^ (z >> 31);
}
static float next_unit(void) {
    return (float)((next_u64() >> 40) * (1.0 / (1 << 24))) * 2.0f - 1.0f;
}
static float *rand_matrix(size_t rows, size_t cols) {
    float *m = malloc(rows * cols * sizeof(float));
    for (size_t i = 0; i < rows * cols; i++)
        m[i] = next_unit();
    return m;
}

static uint64_t ulp_distance(float x, float y) {
    uint32_t bx, by;
    memcpy(&bx, &x, 4);
    memcpy(&by, &y, 4);
    int64_t ox = (bx & 0x80000000u) ? -(int64_t)(bx & 0x7FFFFFFFu) : (int64_t)bx;
    int64_t oy = (by & 0x80000000u) ? -(int64_t)(by & 0x7FFFFFFFu) : (int64_t)by;
    int64_t d = ox - oy;
    return (uint64_t)(d < 0 ? -d : d);
}

/* nanokernel.rs gamma / verify_fma_relaxed (bias-free form) */
static double gamma_n(size_t terms) {
    const double u = 5.9604644775390625e-8; /* 2^-24 */
    double nu = (double)terms * u;
    return nu / (1.0 - nu);
}

static int verify_fma_relaxed(const float *got, const float *want,
                              const float *a, const float *b, const float *c,
                              size_t m, size_t n, size_t k, uint64_t *max_ulp) {
    double *scale = malloc(m * n * sizeof(double));
    for (size_t i = 0; i < m * n; i++)
        scale[i] = fabs((double)c[i]);
    for (size_t i = 0; i < m; i++)
        for (size_t p = 0; p < k; p++) {
            double aa = fabs((double)a[i * k + p]);
            const float *brow = b + p * n;
            for (size_t j = 0; j < n; j++)
                scale[i * n + j] += aa * fabs((double)brow[j]);
        }
    double g = 2.0 * gamma_n(k + 2);
    *max_ulp = 0;
    int ok = 1;
    for (size_t idx = 0; idx < m * n; idx++) {
        double err = fabs((double)got[idx] - (double)want[idx]);
        double bound = g * scale[idx] + 1e-30;
        if (err > bound) {
            fprintf(stderr,
                    "FAIL tolerance at %zu: |diff| %.3e > bound %.3e "
                    "(%" PRIu64 " ulp, k=%zu)\n",
                    idx, err, bound, ulp_distance(got[idx], want[idx]), k);
            ok = 0;
            break;
        }
        uint64_t u = ulp_distance(got[idx], want[idx]);
        if (u > *max_ulp)
            *max_ulp = u;
    }
    free(scale);
    return ok;
}

static int bitwise_equal(const float *x, const float *y, size_t len) {
    return memcmp(x, y, len * sizeof(float)) == 0;
}

static double now_sec(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

static int g_failures = 0;
static void check(int ok, const char *what) {
    printf("%s  %s\n", ok ? "ok  " : "FAIL", what);
    if (!ok)
        g_failures++;
}

static void verify_shape(size_t m, size_t n, size_t k) {
    rng_state = 0x51D + m * 1000 + n * 10 + k;
    float *a = rand_matrix(m, k);
    float *b = rand_matrix(k, n);
    float *c = rand_matrix(m, n);
    size_t len = m * n;
    float *want = malloc(len * sizeof(float));
    float *got = malloc(len * sizeof(float));
    char label[128];
    /* nc = 64 reaches the widest register tiles (24-wide ymm, 32-wide
     * zmm); kc = 6 exercises the k-unroll epilogue — in lockstep with
     * nanokernel.rs simd_vs_naive. */
    blocking_t small = {8, 6, 64};

    memcpy(want, c, len * sizeof(float));
    gemm_naive(want, a, b, m, n, k);

    memcpy(got, c, len * sizeof(float));
    gemm_tiled(got, a, b, m, n, k, small);
    snprintf(label, sizeof label, "tiled(8,6,64) bitwise == naive at %zux%zux%zu", m, n, k);
    check(bitwise_equal(got, want, len), label);

    memcpy(got, c, len * sizeof(float));
    gemm_portable_nano(got, a, b, m, n, k, small);
    snprintf(label, sizeof label, "portable nano bitwise == naive at %zux%zux%zu", m, n, k);
    check(bitwise_equal(got, want, len), label);

    memcpy(got, c, len * sizeof(float));
    gemm_banded(got, a, b, m, n, k, small, 3, ENGINE_AVX2);
    float *single = malloc(len * sizeof(float));
    memcpy(single, c, len * sizeof(float));
    gemm_banded(single, a, b, m, n, k, small, 1, ENGINE_AVX2);
    snprintf(label, sizeof label, "banded avx2 (t=3) bitwise == single at %zux%zux%zu", m, n, k);
    check(bitwise_equal(got, single, len), label);

    uint64_t max_ulp = 0;
    snprintf(label, sizeof label, "avx2 nano meets fma_relaxed bound at %zux%zux%zu", m, n, k);
    check(verify_fma_relaxed(single, want, a, b, c, m, n, k, &max_ulp), label);
    printf("      max ulp vs oracle: %" PRIu64 "\n", max_ulp);

    if (mirror_have_avx512()) {
        memcpy(got, c, len * sizeof(float));
        gemm_banded(got, a, b, m, n, k, small, 3, ENGINE_AVX512);
        memcpy(single, c, len * sizeof(float));
        gemm_banded(single, a, b, m, n, k, small, 1, ENGINE_AVX512);
        snprintf(label, sizeof label, "banded avx512 (t=3) bitwise == single at %zux%zux%zu", m, n, k);
        check(bitwise_equal(got, single, len), label);
        snprintf(label, sizeof label, "avx512 nano meets fma_relaxed bound at %zux%zux%zu", m, n, k);
        check(verify_fma_relaxed(single, want, a, b, c, m, n, k, &max_ulp), label);
        printf("      max ulp vs oracle: %" PRIu64 "\n", max_ulp);
    } else {
        printf("skip  avx512 nano checks at %zux%zux%zu (no avx512f on this host)\n",
               m, n, k);
    }

    free(a); free(b); free(c); free(want); free(got); free(single);
}

typedef struct {
    const char *name;
    blocking_t bs;
    size_t threads;
    int engine; /* ENGINE_* for banded; ignored for naive/tiled */
    int naive;
} policy_t;

/* --- plan passes 1-3 (rust/src/plan/mod.rs under PlanEnv::default):
 * tile selection over autotune::cpu_blockings under the traffic model,
 * the packing decision, and thread partitioning.  Scalar lowering only
 * (the auto pipeline never lowers to SIMD), mirroring the bench's
 * plan:<compiled> row which compiles with PlanEnv::default() on f32.
 * Python twin: python/tests/test_plan_mirror.py compile_plan(). */

#define PLAN_L2_BYTES (256 * 1024)
#define PLAN_L3_BYTES (8 * 1024 * 1024)
#define MIN_FLOPS_PER_THREAD 4e6

static size_t ceil_div(size_t x, size_t d) { return d == 0 ? 0 : (x + d - 1) / d; }

/* plan::traffic_elems — modeled element traffic of one blocked sweep */
static double traffic_elems(size_t m, size_t n, size_t k, blocking_t bs) {
    double a = (double)(m * k) * (double)ceil_div(n, bs.nc);
    double b = (double)(k * n);
    double c = 2.0 * (double)(m * n) * (double)ceil_div(k, bs.kc);
    return a + b + c;
}

typedef struct {
    blocking_t bs;   /* pass 1 */
    int packed;      /* pass 2 */
    size_t bands;    /* pass 3 (1 when !packed) */
    char kernel[64]; /* lowered KernelPolicy name */
} plan_t;

static plan_t plan_compile(size_t m, size_t n, size_t k, size_t hw) {
    /* autotune::cpu_blockings, same enumeration order */
    static const size_t mcs[] = {64, 128, 256};
    static const size_t kcs[] = {128, 256, 512};
    static const size_t ncs[] = {256, 1024};
    blocking_t best = {0, 0, 0};
    double best_traffic = 0.0;
    size_t best_panels = 0;
    int have = 0;
    /* Pass 1: feasible candidates (A panel in L2/2, B panel in L3/2)
     * ranked by traffic; ties toward smaller packed panels, then the
     * larger mc/kc/nc — the strict total order plan.rs min_by_key uses.
     * The full candidate set never goes entirely infeasible, so the
     * Rust fallback-to-all branch is unreachable here. */
    for (size_t i = 0; i < 3; i++)
        for (size_t j = 0; j < 3; j++)
            for (size_t l = 0; l < 2; l++) {
                blocking_t b = {mcs[i], kcs[j], ncs[l]};
                if (b.mc * b.kc * 4 > PLAN_L2_BYTES / 2 ||
                    b.kc * b.nc * 4 > PLAN_L3_BYTES / 2)
                    continue;
                double t = traffic_elems(m, n, k, b);
                size_t panels = (b.mc * b.kc + b.kc * b.nc) * 4;
                int wins =
                    !have || t < best_traffic ||
                    (t == best_traffic &&
                     (panels < best_panels ||
                      (panels == best_panels &&
                       (b.mc > best.mc ||
                        (b.mc == best.mc &&
                         (b.kc > best.kc ||
                          (b.kc == best.kc && b.nc > best.nc)))))));
                if (wins) {
                    best = b;
                    best_traffic = t;
                    best_panels = panels;
                    have = 1;
                }
            }
    plan_t p;
    p.bs = best;
    /* Pass 2: operand footprint within half of L2 runs the direct kernel */
    p.packed = 4.0 * ((double)(m * k) + (double)(k * n) + (double)(m * n)) >
               (double)(PLAN_L2_BYTES / 2);
    /* Pass 3 (pool_threads == 1 in the default env) */
    if (!p.packed) {
        p.bands = 1;
    } else {
        size_t by_work =
            (size_t)(2.0 * (double)m * (double)n * (double)k / MIN_FLOPS_PER_THREAD);
        if (by_work < 1)
            by_work = 1;
        size_t bands = hw < by_work ? hw : by_work;
        size_t row_panels = ceil_div(m, MR);
        if (bands > row_panels)
            bands = row_panels;
        p.bands = bands < 1 ? 1 : bands;
    }
    if (!p.packed)
        snprintf(p.kernel, sizeof p.kernel, "naive");
    else if (p.bands > 1)
        snprintf(p.kernel, sizeof p.kernel, "threaded:%zu,%zu,%zu,%zu",
                 p.bs.mc, p.bs.kc, p.bs.nc, p.bands);
    else
        snprintf(p.kernel, sizeof p.kernel, "tiled:%zu,%zu,%zu",
                 p.bs.mc, p.bs.kc, p.bs.nc);
    return p;
}

static void bench_size(size_t size) {
    rng_state = 0xBE7C4 + size;
    float *a = rand_matrix(size, size);
    float *b = rand_matrix(size, size);
    float *c = rand_matrix(size, size);
    float *out = malloc(size * size * sizeof(float));
    float *want = malloc(size * size * sizeof(float));
    double flops = 2.0 * (double)size * (double)size * (double)size;

    memcpy(want, c, size * size * sizeof(float));
    gemm_naive(want, a, b, size, size, size);

    policy_t policies[] = {
        {"naive", DEFAULT_BLOCKING, 1, ENGINE_SCALAR, 1},
        {"tiled", DEFAULT_BLOCKING, 1, ENGINE_SCALAR, 0},
        {"threaded", DEFAULT_BLOCKING, 0, ENGINE_SCALAR, 0},
        {"simd:avx2", DEFAULT_BLOCKING, 0, ENGINE_AVX2, 0},
        {"simd:avx512", DEFAULT_BLOCKING, 0, ENGINE_AVX512, 0},
    };
    for (size_t pi = 0; pi < sizeof policies / sizeof *policies; pi++) {
        policy_t *p = &policies[pi];
        if (p->engine == ENGINE_AVX512 && !mirror_have_avx512()) {
            printf("skip  %s at %zu (no avx512f on this host)\n", p->name, size);
            continue;
        }
        double best = 1e30;
        int reps = 0;
        double budget = now_sec() + (size >= 2048 ? 8.0 : 3.0);
        do {
            memcpy(out, c, size * size * sizeof(float));
            double t0 = now_sec();
            if (p->naive)
                gemm_naive(out, a, b, size, size, size);
            else if (p->threads == 1 && p->engine == ENGINE_SCALAR)
                gemm_tiled(out, a, b, size, size, size, p->bs);
            else
                gemm_banded(out, a, b, size, size, size, p->bs, p->threads, p->engine);
            double dt = now_sec() - t0;
            if (dt < best)
                best = dt;
            reps++;
        } while (reps < 3 || (now_sec() < budget && reps < 12));
        if (p->engine != ENGINE_SCALAR) {
            uint64_t max_ulp;
            if (!verify_fma_relaxed(out, want, a, b, c, size, size, size, &max_ulp))
                g_failures++;
            printf("{\"size\": %zu, \"policy\": \"%s\", \"best_seconds\": %.6f, "
                   "\"gflops\": %.3f, \"max_ulp\": %" PRIu64 "}\n",
                   size, p->name, best, flops / best / 1e9, max_ulp);
        } else {
            if (!bitwise_equal(out, want, size * size)) {
                fprintf(stderr, "FAIL %s not bitwise at %zu\n", p->name, size);
                g_failures++;
            }
            printf("{\"size\": %zu, \"policy\": \"%s\", \"best_seconds\": %.6f, "
                   "\"gflops\": %.3f}\n",
                   size, p->name, best, flops / best / 1e9);
        }
        fflush(stdout);
    }

    /* plan:<compiled> — what the exec_kernel bench's plan row runs: the
     * kernel lowered by plan passes 1-3 under the default environment.
     * Scalar lowering, so bit-equality vs naive is the check. */
    {
        long nproc = sysconf(_SC_NPROCESSORS_ONLN);
        size_t hw = nproc > 0 ? (size_t)nproc : 1;
        plan_t p = plan_compile(size, size, size, hw);
        char name[80];
        snprintf(name, sizeof name, "plan:%s", p.kernel);
        double best = 1e30;
        int reps = 0;
        double budget = now_sec() + (size >= 2048 ? 8.0 : 3.0);
        do {
            memcpy(out, c, size * size * sizeof(float));
            double t0 = now_sec();
            if (!p.packed)
                gemm_naive(out, a, b, size, size, size);
            else if (p.bands == 1)
                gemm_tiled(out, a, b, size, size, size, p.bs);
            else
                gemm_banded(out, a, b, size, size, size, p.bs, p.bands, 0);
            double dt = now_sec() - t0;
            if (dt < best)
                best = dt;
            reps++;
        } while (reps < 3 || (now_sec() < budget && reps < 12));
        if (!bitwise_equal(out, want, size * size)) {
            fprintf(stderr, "FAIL %s not bitwise at %zu\n", name, size);
            g_failures++;
        }
        printf("{\"size\": %zu, \"policy\": \"%s\", \"best_seconds\": %.6f, "
               "\"gflops\": %.3f}\n",
               size, name, best, flops / best / 1e9);
        fflush(stdout);
    }
    free(a); free(b); free(c); free(out); free(want);
}

int main(int argc, char **argv) {
    /* the ragged shape family from nanokernel.rs tests + bench sizes */
    size_t shapes[][3] = {
        {1, 1, 1}, {1, 17, 5}, {19, 1, 7}, {4, 16, 8}, {5, 17, 9},
        {4, 35, 12}, {33, 7, 21}, {40, 40, 40}, {96, 64, 48}, {128, 96, 112},
        {5, 57, 13}, {7, 100, 30},
    };
    for (size_t i = 0; i < sizeof shapes / sizeof *shapes; i++)
        verify_shape(shapes[i][0], shapes[i][1], shapes[i][2]);
    /* plan passes 1-3 against the pinned-env decision points the Python
     * mirror and the Rust goldens agree on (hw pinned to 4 like
     * PlanEnv::pinned so the checks are host-independent) */
    check(strcmp(plan_compile(64, 64, 64, 4).kernel, "naive") == 0,
          "plan(64^3) lowers to the direct kernel");
    check(strcmp(plan_compile(256, 256, 256, 4).kernel, "threaded:64,256,256,4") == 0,
          "plan(256^3, hw=4) == threaded:64,256,256,4");
    check(strcmp(plan_compile(512, 512, 512, 4).kernel, "threaded:64,512,1024,4") == 0,
          "plan(512^3, hw=4) == threaded:64,512,1024,4");
    check(plan_compile(8, 2048, 2048, 4).bands == 2,
          "plan(8x2048x2048) caps bands at ceil(m/MR) = 2");
    if (argc > 1 && strcmp(argv[1], "--verify-only") == 0) {
        printf(g_failures ? "VERIFY: %d failure(s)\n" : "VERIFY: all checks passed\n",
               g_failures);
        return g_failures != 0;
    }
    size_t sizes[] = {256, 512, 1024, 2048};
    for (size_t i = 0; i < sizeof sizes / sizeof *sizes; i++)
        bench_size(sizes[i]);
    printf(g_failures ? "DONE: %d failure(s)\n" : "DONE: all checks passed\n",
           g_failures);
    return g_failures != 0;
}
