/* Line-by-line C mirror of nanokernel.rs avx512::macro_kernel — the
 * 4x32 AVX-512F register tile (8 zmm accumulators: 4 rows x 2 zmm of
 * 16 lanes, 2 B loads + 4 A broadcasts + 8 vfmadd231ps per k step),
 * k-unrolled by 4 with a software prefetch of the B/A panel rows 4
 * k-steps ahead, a masked 16-lane j remainder (`__mmask16` maskz load
 * / mask store, so partial columns never touch memory outside the
 * tile), and the ragged-row fmaf() tail.
 *
 * Each accumulator is an independent FMA chain in strict increasing-k
 * order; the unroll repeats the step body without reassociating any
 * chain, so every output element sees one any-order FMA accumulation —
 * the shape the fma_relaxed bound (DESIGN.md §10) covers.
 *
 * This is the ONLY translation unit built with -mavx512f.  Callers
 * gate on mirror_have_avx512(); the probe itself needs no avx512
 * codegen and is safe on any x86-64.  -ffp-contract=off as everywhere:
 * all fusion below is explicit intrinsics or fmaf.
 */
#include "mirror.h"

#include <immintrin.h>
#include <math.h>

int mirror_have_avx512(void) { return __builtin_cpu_supports("avx512f"); }

void avx512_macro_kernel(float *out, size_t ldc, size_t ic, size_t mcb,
                         size_t jc, size_t ncb, size_t kcb,
                         const float *apack, const float *bpack) {
    size_t full_panels = mcb / MR;
    for (size_t pi = 0; pi < full_panels; pi++) {
        size_t i0 = ic + pi * MR;
        const float *ap = apack + pi * MR * kcb;
        float *o0 = out + i0 * ldc + jc;
        float *o1 = o0 + ldc, *o2 = o1 + ldc, *o3 = o2 + ldc;
        size_t j = 0;
        for (; j + 32 <= ncb; j += 32) {
            __m512 c00 = _mm512_loadu_ps(o0 + j);
            __m512 c01 = _mm512_loadu_ps(o0 + j + 16);
            __m512 c10 = _mm512_loadu_ps(o1 + j);
            __m512 c11 = _mm512_loadu_ps(o1 + j + 16);
            __m512 c20 = _mm512_loadu_ps(o2 + j);
            __m512 c21 = _mm512_loadu_ps(o2 + j + 16);
            __m512 c30 = _mm512_loadu_ps(o3 + j);
            __m512 c31 = _mm512_loadu_ps(o3 + j + 16);
            const float *bp = bpack + j;
            const float *apk = ap;
            size_t p = 0;
#define STEP512                                                            \
    do {                                                                   \
        __m512 b0 = _mm512_loadu_ps(bp);                                   \
        __m512 b1 = _mm512_loadu_ps(bp + 16);                              \
        __m512 a0 = _mm512_set1_ps(apk[0]);                                \
        __m512 a1 = _mm512_set1_ps(apk[1]);                                \
        __m512 a2 = _mm512_set1_ps(apk[2]);                                \
        __m512 a3 = _mm512_set1_ps(apk[3]);                                \
        c00 = _mm512_fmadd_ps(a0, b0, c00);                                \
        c01 = _mm512_fmadd_ps(a0, b1, c01);                                \
        c10 = _mm512_fmadd_ps(a1, b0, c10);                                \
        c11 = _mm512_fmadd_ps(a1, b1, c11);                                \
        c20 = _mm512_fmadd_ps(a2, b0, c20);                                \
        c21 = _mm512_fmadd_ps(a2, b1, c21);                                \
        c30 = _mm512_fmadd_ps(a3, b0, c30);                                \
        c31 = _mm512_fmadd_ps(a3, b1, c31);                                \
        bp += ncb;                                                         \
        apk += MR;                                                         \
    } while (0)
            for (; p + 4 <= kcb; p += 4) {
                _mm_prefetch((const char *)(bp + 4 * ncb), _MM_HINT_T0);
                _mm_prefetch((const char *)(bp + 4 * ncb + 16), _MM_HINT_T0);
                _mm_prefetch((const char *)(apk + 4 * MR), _MM_HINT_T0);
                STEP512;
                STEP512;
                STEP512;
                STEP512;
            }
            for (; p < kcb; p++)
                STEP512;
#undef STEP512
            _mm512_storeu_ps(o0 + j, c00);
            _mm512_storeu_ps(o0 + j + 16, c01);
            _mm512_storeu_ps(o1 + j, c10);
            _mm512_storeu_ps(o1 + j + 16, c11);
            _mm512_storeu_ps(o2 + j, c20);
            _mm512_storeu_ps(o2 + j + 16, c21);
            _mm512_storeu_ps(o3 + j, c30);
            _mm512_storeu_ps(o3 + j + 16, c31);
        }
        for (; j < ncb; j += 16) {
            size_t rem = ncb - j;
            __mmask16 msk = rem >= 16 ? (__mmask16)0xFFFF
                                      : (__mmask16)((1u << rem) - 1);
            __m512 c0 = _mm512_maskz_loadu_ps(msk, o0 + j);
            __m512 c1 = _mm512_maskz_loadu_ps(msk, o1 + j);
            __m512 c2 = _mm512_maskz_loadu_ps(msk, o2 + j);
            __m512 c3 = _mm512_maskz_loadu_ps(msk, o3 + j);
            const float *bp = bpack + j;
            const float *apk = ap;
            for (size_t p = 0; p < kcb; p++) {
                __m512 b0 = _mm512_maskz_loadu_ps(msk, bp);
                c0 = _mm512_fmadd_ps(_mm512_set1_ps(apk[0]), b0, c0);
                c1 = _mm512_fmadd_ps(_mm512_set1_ps(apk[1]), b0, c1);
                c2 = _mm512_fmadd_ps(_mm512_set1_ps(apk[2]), b0, c2);
                c3 = _mm512_fmadd_ps(_mm512_set1_ps(apk[3]), b0, c3);
                bp += ncb;
                apk += MR;
            }
            _mm512_mask_storeu_ps(o0 + j, msk, c0);
            _mm512_mask_storeu_ps(o1 + j, msk, c1);
            _mm512_mask_storeu_ps(o2 + j, msk, c2);
            _mm512_mask_storeu_ps(o3 + j, msk, c3);
        }
    }
    for (size_t i = full_panels * MR; i < mcb; i++) {
        size_t pi = i / MR, ir = i % MR;
        const float *ap = apack + pi * MR * kcb;
        for (size_t j = 0; j < ncb; j++) {
            size_t idx = (ic + i) * ldc + jc + j;
            float x = out[idx];
            for (size_t p = 0; p < kcb; p++)
                x = fmaf(ap[p * MR + ir], bpack[p * ncb + j], x);
            out[idx] = x;
        }
    }
}
