/* Shared declarations for the C mirror of the Rust GEMM engine.
 * See README.md in this directory for what the mirror is for and how
 * faithfully it tracks rust/src/runtime/{kernel,nanokernel}.rs. */
#ifndef MIRROR_H
#define MIRROR_H

#include <stddef.h>

#define MR 4
#define NR 4

typedef struct {
    size_t mc, kc, nc;
} blocking_t;

/* kernel.rs DEFAULT_BLOCKING */
#define DEFAULT_BLOCKING ((blocking_t){128, 256, 1024})

/* nanokernel engines selectable in gemm_banded, mirroring the Isa enum
 * (scalar == the Micro::Scalar macro kernel, not PortableNano) */
#define ENGINE_SCALAR 0
#define ENGINE_AVX2 1
#define ENGINE_AVX512 2

/* naive i-k-j reference: out += a @ b (out holds C on entry) */
void gemm_naive(float *out, const float *a, const float *b,
                size_t m, size_t n, size_t k);

/* scalar tiled kernel (pack_a/pack_b + MRxNR micro kernel), one thread */
void gemm_tiled(float *out, const float *a, const float *b,
                size_t m, size_t n, size_t k, blocking_t bs);

/* row-banded threading over the tiled kernel; threads==0 probes nproc.
 * engine is one of ENGINE_* and swaps the macro kernel for the matching
 * nanokernel body (ENGINE_AVX512 requires mirror_have_avx512()). */
void gemm_banded(float *out, const float *a, const float *b,
                 size_t m, size_t n, size_t k, blocking_t bs,
                 size_t threads, int engine);

/* portable 4-wide nanokernel (nanokernel.rs PortableNano), one thread */
void gemm_portable_nano(float *out, const float *a, const float *b,
                        size_t m, size_t n, size_t k, blocking_t bs);

/* nanokernel.rs avx2::macro_kernel — defined in mirror_avx2.c, which is
 * the only translation unit built with -mavx2 -mfma */
void avx2_macro_kernel(float *out, size_t ldc, size_t ic, size_t mcb,
                       size_t jc, size_t ncb, size_t kcb,
                       const float *apack, const float *bpack);

/* nanokernel.rs avx512::macro_kernel — defined in mirror_avx512.c, the
 * only translation unit built with -mavx512f.  Callers must gate on
 * mirror_have_avx512() (runtime cpuid probe, safe to call anywhere). */
void avx512_macro_kernel(float *out, size_t ldc, size_t ic, size_t mcb,
                         size_t jc, size_t ncb, size_t kcb,
                         const float *apack, const float *bpack);
int mirror_have_avx512(void);

#endif
