/* Line-by-line C mirror of nanokernel.rs avx2::macro_kernel — the
 * tuned 4x24 AVX2+FMA register tile (12 ymm accumulators, 3 B loads +
 * 4 A broadcasts + 12 vfmadd231ps per k step), k-unrolled by 4 with a
 * software prefetch of the B/A panel rows 4 k-steps ahead, then the
 * 8-wide j remainder, the scalar fmaf() j tail, and the ragged-row
 * fmaf() tail.
 *
 * Each of the 12 accumulators is an independent FMA chain in strict
 * increasing-k order; the k-unroll only repeats the step body, it does
 * not split or reassociate any accumulator, so the rounding sequence
 * per output element is a single any-order FMA accumulation — exactly
 * the shape the fma_relaxed bound (DESIGN.md §10) covers.
 *
 * This is the ONLY translation unit built with -mavx2 -mfma.  It still
 * uses -ffp-contract=off: every fused multiply-add below is explicit
 * (an intrinsic or fmaf), exactly as in the Rust body, so the mirror's
 * rounding sequence is the one the fma_relaxed contract describes.
 */
#include "mirror.h"

#include <immintrin.h>
#include <math.h>

void avx2_macro_kernel(float *out, size_t ldc, size_t ic, size_t mcb,
                       size_t jc, size_t ncb, size_t kcb,
                       const float *apack, const float *bpack) {
    size_t full_panels = mcb / MR;
    for (size_t pi = 0; pi < full_panels; pi++) {
        size_t i0 = ic + pi * MR;
        const float *ap = apack + pi * MR * kcb;
        float *o0 = out + i0 * ldc + jc;
        float *o1 = o0 + ldc, *o2 = o1 + ldc, *o3 = o2 + ldc;
        size_t j = 0;
        for (; j + 24 <= ncb; j += 24) {
            __m256 c00 = _mm256_loadu_ps(o0 + j);
            __m256 c01 = _mm256_loadu_ps(o0 + j + 8);
            __m256 c02 = _mm256_loadu_ps(o0 + j + 16);
            __m256 c10 = _mm256_loadu_ps(o1 + j);
            __m256 c11 = _mm256_loadu_ps(o1 + j + 8);
            __m256 c12 = _mm256_loadu_ps(o1 + j + 16);
            __m256 c20 = _mm256_loadu_ps(o2 + j);
            __m256 c21 = _mm256_loadu_ps(o2 + j + 8);
            __m256 c22 = _mm256_loadu_ps(o2 + j + 16);
            __m256 c30 = _mm256_loadu_ps(o3 + j);
            __m256 c31 = _mm256_loadu_ps(o3 + j + 8);
            __m256 c32 = _mm256_loadu_ps(o3 + j + 16);
            const float *bp = bpack + j;
            const float *apk = ap;
            size_t p = 0;
#define STEP24                                                             \
    do {                                                                   \
        __m256 b0 = _mm256_loadu_ps(bp);                                   \
        __m256 b1 = _mm256_loadu_ps(bp + 8);                               \
        __m256 b2 = _mm256_loadu_ps(bp + 16);                              \
        __m256 aa = _mm256_set1_ps(apk[0]);                                \
        c00 = _mm256_fmadd_ps(aa, b0, c00);                                \
        c01 = _mm256_fmadd_ps(aa, b1, c01);                                \
        c02 = _mm256_fmadd_ps(aa, b2, c02);                                \
        aa = _mm256_set1_ps(apk[1]);                                       \
        c10 = _mm256_fmadd_ps(aa, b0, c10);                                \
        c11 = _mm256_fmadd_ps(aa, b1, c11);                                \
        c12 = _mm256_fmadd_ps(aa, b2, c12);                                \
        aa = _mm256_set1_ps(apk[2]);                                       \
        c20 = _mm256_fmadd_ps(aa, b0, c20);                                \
        c21 = _mm256_fmadd_ps(aa, b1, c21);                                \
        c22 = _mm256_fmadd_ps(aa, b2, c22);                                \
        aa = _mm256_set1_ps(apk[3]);                                       \
        c30 = _mm256_fmadd_ps(aa, b0, c30);                                \
        c31 = _mm256_fmadd_ps(aa, b1, c31);                                \
        c32 = _mm256_fmadd_ps(aa, b2, c32);                                \
        bp += ncb;                                                         \
        apk += MR;                                                         \
    } while (0)
            for (; p + 4 <= kcb; p += 4) {
                _mm_prefetch((const char *)(bp + 4 * ncb), _MM_HINT_T0);
                _mm_prefetch((const char *)(apk + 4 * MR), _MM_HINT_T0);
                STEP24;
                STEP24;
                STEP24;
                STEP24;
            }
            for (; p < kcb; p++)
                STEP24;
#undef STEP24
            _mm256_storeu_ps(o0 + j, c00);
            _mm256_storeu_ps(o0 + j + 8, c01);
            _mm256_storeu_ps(o0 + j + 16, c02);
            _mm256_storeu_ps(o1 + j, c10);
            _mm256_storeu_ps(o1 + j + 8, c11);
            _mm256_storeu_ps(o1 + j + 16, c12);
            _mm256_storeu_ps(o2 + j, c20);
            _mm256_storeu_ps(o2 + j + 8, c21);
            _mm256_storeu_ps(o2 + j + 16, c22);
            _mm256_storeu_ps(o3 + j, c30);
            _mm256_storeu_ps(o3 + j + 8, c31);
            _mm256_storeu_ps(o3 + j + 16, c32);
        }
        for (; j + 8 <= ncb; j += 8) {
            __m256 c0 = _mm256_loadu_ps(o0 + j);
            __m256 c1 = _mm256_loadu_ps(o1 + j);
            __m256 c2 = _mm256_loadu_ps(o2 + j);
            __m256 c3 = _mm256_loadu_ps(o3 + j);
            const float *bp = bpack + j;
            const float *apk = ap;
            for (size_t p = 0; p < kcb; p++) {
                __m256 b0 = _mm256_loadu_ps(bp);
                c0 = _mm256_fmadd_ps(_mm256_set1_ps(apk[0]), b0, c0);
                c1 = _mm256_fmadd_ps(_mm256_set1_ps(apk[1]), b0, c1);
                c2 = _mm256_fmadd_ps(_mm256_set1_ps(apk[2]), b0, c2);
                c3 = _mm256_fmadd_ps(_mm256_set1_ps(apk[3]), b0, c3);
                bp += ncb;
                apk += MR;
            }
            _mm256_storeu_ps(o0 + j, c0);
            _mm256_storeu_ps(o1 + j, c1);
            _mm256_storeu_ps(o2 + j, c2);
            _mm256_storeu_ps(o3 + j, c3);
        }
        for (; j < ncb; j++) {
            for (size_t r = 0; r < MR; r++) {
                float *op = out + (i0 + r) * ldc + jc + j;
                float x = *op;
                for (size_t p = 0; p < kcb; p++)
                    x = fmaf(ap[p * MR + r], bpack[p * ncb + j], x);
                *op = x;
            }
        }
    }
    for (size_t i = full_panels * MR; i < mcb; i++) {
        size_t pi = i / MR, ir = i % MR;
        const float *ap = apack + pi * MR * kcb;
        for (size_t j = 0; j < ncb; j++) {
            size_t idx = (ic + i) * ldc + jc + j;
            float x = out[idx];
            for (size_t p = 0; p < kcb; p++)
                x = fmaf(ap[p * MR + ir], bpack[p * ncb + j], x);
            out[idx] = x;
        }
    }
}
