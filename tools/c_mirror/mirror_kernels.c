/* Line-by-line C mirror of rust/src/runtime/kernel.rs (naive, packed
 * tiled, row-banded) and the portable nanokernel from
 * rust/src/runtime/nanokernel.rs.
 *
 * This translation unit is deliberately built at the baseline x86-64
 * level with -ffp-contract=off: rustc never contracts a*b+c into an
 * FMA, so neither may the mirror's scalar paths — bit-identity with
 * the naive reference is part of what the mirror validates.
 */
#include "mirror.h"

#include <pthread.h>
#include <stdlib.h>
#include <string.h>
#include <unistd.h>

static size_t ceil_div(size_t x, size_t d) { return x / d + (x % d != 0); }
static size_t round_up(size_t x, size_t m) { return ceil_div(x, m) * m; }
static size_t min_sz(size_t a, size_t b) { return a < b ? a : b; }

void gemm_naive(float *out, const float *a, const float *b,
                size_t m, size_t n, size_t k) {
    for (size_t i = 0; i < m; i++) {
        float *orow = out + i * n;
        for (size_t p = 0; p < k; p++) {
            const float av = a[i * k + p];
            const float *brow = b + p * n;
            for (size_t j = 0; j < n; j++)
                orow[j] += av * brow[j];
        }
    }
}

/* kernel.rs pack_a: MR-row panels, p-major inside a panel, zero-padded */
static void pack_a(float *apack, const float *a, size_t lda, size_t ic,
                   size_t mcb, size_t pc, size_t kcb) {
    size_t panels = ceil_div(mcb, MR);
    for (size_t pi = 0; pi < panels; pi++) {
        float *dst = apack + pi * MR * kcb;
        size_t i0 = ic + pi * MR;
        size_t rows = min_sz(MR, ic + mcb - i0);
        for (size_t p = 0; p < kcb; p++) {
            float *d = dst + p * MR;
            for (size_t i = 0; i < rows; i++)
                d[i] = a[(i0 + i) * lda + pc + p];
            for (size_t i = rows; i < MR; i++)
                d[i] = 0.0f;
        }
    }
}

/* kernel.rs pack_b: contiguous kcb x ncb row-major panel */
static void pack_b(float *bpack, const float *b, size_t ldb, size_t pc,
                   size_t kcb, size_t jc, size_t ncb) {
    for (size_t p = 0; p < kcb; p++)
        memcpy(bpack + p * ncb, b + (pc + p) * ldb + jc, ncb * sizeof(float));
}

static void saxpy(float *orow, float av, const float *brow, size_t ncb) {
    for (size_t j = 0; j < ncb; j++)
        orow[j] += av * brow[j];
}

/* kernel.rs micro_kernel: MR C rows x NR staged k-steps, plain mul+add
 * in increasing-k order */
static void micro_kernel(const float *ab, const float *bp, size_t ncb,
                         float *o0, float *o1, float *o2, float *o3) {
    const float *b0 = bp, *b1 = bp + ncb, *b2 = bp + 2 * ncb, *b3 = bp + 3 * ncb;
    for (size_t j = 0; j < ncb; j++) {
        const float bv0 = b0[j], bv1 = b1[j], bv2 = b2[j], bv3 = b3[j];
        float x0 = o0[j];
        x0 += ab[0] * bv0;
        x0 += ab[4] * bv1;
        x0 += ab[8] * bv2;
        x0 += ab[12] * bv3;
        o0[j] = x0;
        float x1 = o1[j];
        x1 += ab[1] * bv0;
        x1 += ab[5] * bv1;
        x1 += ab[9] * bv2;
        x1 += ab[13] * bv3;
        o1[j] = x1;
        float x2 = o2[j];
        x2 += ab[2] * bv0;
        x2 += ab[6] * bv1;
        x2 += ab[10] * bv2;
        x2 += ab[14] * bv3;
        o2[j] = x2;
        float x3 = o3[j];
        x3 += ab[3] * bv0;
        x3 += ab[7] * bv1;
        x3 += ab[11] * bv2;
        x3 += ab[15] * bv3;
        o3[j] = x3;
    }
}

/* kernel.rs macro_kernel (the scalar Micro engine) */
static void scalar_macro_kernel(float *out, size_t ldc, size_t ic, size_t mcb,
                                size_t jc, size_t ncb, size_t kcb,
                                const float *apack, const float *bpack) {
    size_t full_panels = mcb / MR;
    for (size_t pi = 0; pi < full_panels; pi++) {
        size_t i0 = ic + pi * MR;
        const float *ap = apack + pi * MR * kcb;
        float *o0 = out + i0 * ldc + jc;
        float *o1 = o0 + ldc, *o2 = o1 + ldc, *o3 = o2 + ldc;
        size_t p = 0;
        for (; p + NR <= kcb; p += NR)
            micro_kernel(ap + p * MR, bpack + p * ncb, ncb, o0, o1, o2, o3);
        for (; p < kcb; p++) {
            const float *brow = bpack + p * ncb;
            saxpy(o0, ap[p * MR], brow, ncb);
            saxpy(o1, ap[p * MR + 1], brow, ncb);
            saxpy(o2, ap[p * MR + 2], brow, ncb);
            saxpy(o3, ap[p * MR + 3], brow, ncb);
        }
    }
    for (size_t i = full_panels * MR; i < mcb; i++) {
        size_t pi = i / MR, ir = i % MR;
        const float *ap = apack + pi * MR * kcb;
        float *orow = out + (ic + i) * ldc + jc;
        for (size_t p = 0; p < kcb; p++)
            saxpy(orow, ap[p * MR + ir], bpack + p * ncb, ncb);
    }
}

/* nanokernel.rs PortableNano::macro_kernel: MR x 4-lane accumulator
 * tile, plain mul+add, k-streamed with one load/store of the C tile */
#define PW 4
static void portable_macro_kernel(float *out, size_t ldc, size_t ic, size_t mcb,
                                  size_t jc, size_t ncb, size_t kcb,
                                  const float *apack, const float *bpack) {
    size_t full_panels = mcb / MR;
    for (size_t pi = 0; pi < full_panels; pi++) {
        size_t i0 = ic + pi * MR;
        const float *ap = apack + pi * MR * kcb;
        size_t j = 0;
        for (; j + PW <= ncb; j += PW) {
            float acc[MR][PW];
            for (size_t r = 0; r < MR; r++)
                memcpy(acc[r], out + (i0 + r) * ldc + jc + j, PW * sizeof(float));
            for (size_t p = 0; p < kcb; p++) {
                const float *brow = bpack + p * ncb + j;
                for (size_t r = 0; r < MR; r++) {
                    const float av = ap[p * MR + r];
                    for (size_t x = 0; x < PW; x++)
                        acc[r][x] += av * brow[x];
                }
            }
            for (size_t r = 0; r < MR; r++)
                memcpy(out + (i0 + r) * ldc + jc + j, acc[r], PW * sizeof(float));
        }
        for (; j < ncb; j++) {
            for (size_t r = 0; r < MR; r++) {
                float x = out[(i0 + r) * ldc + jc + j];
                for (size_t p = 0; p < kcb; p++)
                    x += ap[p * MR + r] * bpack[p * ncb + j];
                out[(i0 + r) * ldc + jc + j] = x;
            }
        }
    }
    for (size_t i = full_panels * MR; i < mcb; i++) {
        size_t pi = i / MR, ir = i % MR;
        const float *ap = apack + pi * MR * kcb;
        for (size_t j = 0; j < ncb; j++) {
            size_t idx = (ic + i) * ldc + jc + j;
            float x = out[idx];
            for (size_t p = 0; p < kcb; p++)
                x += ap[p * MR + ir] * bpack[p * ncb + j];
            out[idx] = x;
        }
    }
}

typedef void (*macro_fn)(float *, size_t, size_t, size_t, size_t, size_t,
                         size_t, const float *, const float *);

/* kernel.rs aligned_pack_vec: pack buffers are 64-byte aligned so the
 * nanokernels' full-width vector loads never split a cache line (the
 * zmm bodies in particular lose ~30% on split 64-byte loads). */
static float *pack_alloc(size_t elems) {
    void *p = NULL;
    if (posix_memalign(&p, 64, elems * sizeof(float)) != 0)
        return NULL;
    return p;
}

/* kernel.rs gemm_tiled: jc -> pc (increasing k) -> ic cache blocks */
static void tiled_with(float *out, const float *a, const float *b,
                       size_t m, size_t n, size_t k, blocking_t bs,
                       macro_fn engine) {
    size_t mc = bs.mc, kc = bs.kc, nc = bs.nc;
    float *apack = pack_alloc(round_up(min_sz(mc, m), MR) * min_sz(kc, k));
    float *bpack = pack_alloc(min_sz(nc, n) * min_sz(kc, k));
    for (size_t jc = 0; jc < n; jc += nc) {
        size_t ncb = min_sz(nc, n - jc);
        for (size_t pc = 0; pc < k; pc += kc) {
            size_t kcb = min_sz(kc, k - pc);
            pack_b(bpack, b, n, pc, kcb, jc, ncb);
            for (size_t ic = 0; ic < m; ic += mc) {
                size_t mcb = min_sz(mc, m - ic);
                pack_a(apack, a, k, ic, mcb, pc, kcb);
                engine(out, n, ic, mcb, jc, ncb, kcb, apack, bpack);
            }
        }
    }
    free(apack);
    free(bpack);
}

void gemm_tiled(float *out, const float *a, const float *b,
                size_t m, size_t n, size_t k, blocking_t bs) {
    tiled_with(out, a, b, m, n, k, bs, scalar_macro_kernel);
}

void gemm_portable_nano(float *out, const float *a, const float *b,
                        size_t m, size_t n, size_t k, blocking_t bs) {
    tiled_with(out, a, b, m, n, k, bs, portable_macro_kernel);
}

/* kernel.rs gemm_banded: MR-aligned disjoint row bands */
typedef struct {
    float *out;
    const float *a, *b;
    size_t m, n, k;
    blocking_t bs;
    macro_fn engine;
} band_job_t;

static void *band_main(void *arg) {
    band_job_t *jb = arg;
    tiled_with(jb->out, jb->a, jb->b, jb->m, jb->n, jb->k, jb->bs, jb->engine);
    return NULL;
}

void gemm_banded(float *out, const float *a, const float *b,
                 size_t m, size_t n, size_t k, blocking_t bs,
                 size_t threads, int engine_id) {
    macro_fn engine = engine_id == ENGINE_AVX512 ? avx512_macro_kernel
                      : engine_id == ENGINE_AVX2 ? avx2_macro_kernel
                                                 : scalar_macro_kernel;
    size_t hw = threads;
    if (hw == 0) {
        long v = sysconf(_SC_NPROCESSORS_ONLN);
        hw = v > 0 ? (size_t)v : 1;
    }
    double flops = 2.0 * (double)m * (double)n * (double)k;
    size_t by_work = (size_t)(flops / 4e6); /* MIN_FLOPS_PER_THREAD */
    size_t bands = min_sz(hw, by_work > 0 ? by_work : 1);
    bands = min_sz(bands, ceil_div(m, MR));
    if (bands < 1)
        bands = 1;
    if (bands == 1) {
        tiled_with(out, a, b, m, n, k, bs, engine);
        return;
    }
    size_t rows_per = round_up(ceil_div(m, bands), MR);
    size_t nbands = ceil_div(m, rows_per);
    pthread_t tids[64];
    band_job_t jobs[64];
    for (size_t bidx = 0; bidx < nbands; bidx++) {
        size_t row0 = bidx * rows_per;
        size_t bm = min_sz(rows_per, m - row0);
        jobs[bidx] = (band_job_t){out + row0 * n, a + row0 * k, b,
                                  bm, n, k, bs, engine};
        pthread_create(&tids[bidx], NULL, band_main, &jobs[bidx]);
    }
    for (size_t bidx = 0; bidx < nbands; bidx++)
        pthread_join(tids[bidx], NULL);
}
