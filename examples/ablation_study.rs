//! Ablation study (the Figure 3 experiment as a runnable example):
//! executes all eight ablation artifacts on identical inputs, verifies
//! they agree numerically, and prints both the measured CPU wallclock and
//! the simulated RTX 3090 TFLOPs ladder side by side.

use std::path::PathBuf;

use anyhow::{anyhow, Result};
use mlir_gemm::harness::{ablation_schedule, ABLATION_LABELS};
use mlir_gemm::runtime::{ArtifactKind, Runtime, Tensor};
use mlir_gemm::sim::{simulate, DeviceModel};
use mlir_gemm::util::prng::Rng;

fn main() -> Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::open(&dir)?;
    let device = DeviceModel::rtx3090();

    let mut ablations: Vec<_> = rt
        .artifacts()
        .iter()
        .filter(|a| a.kind == ArtifactKind::Ablation)
        .cloned()
        .collect();
    ablations.sort_by_key(|a| a.schedule.as_ref().unwrap().opt_level);
    if ablations.is_empty() {
        return Err(anyhow!("no ablation artifacts; run `make artifacts`"));
    }
    let (m, n, k) = ablations[0].problem.unwrap();
    let mut rng = Rng::new(0);
    let inputs = vec![
        Tensor::new(vec![m, k], rng.normal_matrix(m, k))?,
        Tensor::new(vec![k, n], rng.normal_matrix(k, n))?,
        Tensor::new(vec![m, n], rng.normal_matrix(m, n))?,
    ];

    println!(
        "{:<24} {:>12} {:>16} {:>18}",
        "level", "measured ms", "sim 3090 TFLOPs", "agrees w/ full?"
    );
    let full = rt.execute(&ablations.last().unwrap().name, &inputs)?;
    for a in &ablations {
        let sched = a.schedule.as_ref().unwrap();
        let loaded = rt.load(&a.name)?;
        // warm + one timed run (full protocol lives in `cargo bench fig3`)
        rt.execute_timed(&loaded, &inputs)?;
        let (out, t) = rt.execute_timed(&loaded, &inputs)?;
        let mut num = 0f64;
        let mut den = 0f64;
        for (g, w) in out[0].data.iter().zip(&full[0].data) {
            num += ((g - w) as f64).powi(2);
            den += (*w as f64).powi(2);
        }
        let agrees = (num / den.max(1e-30)).sqrt() < 2e-3;
        let sim_tf = simulate(&ablation_schedule(sched.opt_level, 8192), &device).tflops;
        println!(
            "{:<24} {:>12.3} {:>16.2} {:>18}",
            ABLATION_LABELS[sched.opt_level as usize],
            t.exec_seconds * 1e3,
            sim_tf,
            if agrees { "yes" } else { "NO" },
        );
        assert!(agrees, "{} diverges from full pipeline", a.name);
    }
    println!("\nablation_study OK (sim column reproduces the Figure 3 ladder)");
    Ok(())
}
