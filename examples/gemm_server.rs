//! GEMM-as-a-service: the L3 coordinator serving batched requests.
//!
//! Spins up the server over the built artifacts, fires a mixed workload
//! (several shapes, fused and plain epilogues, occasional baseline routes)
//! from multiple client threads, and prints the latency/throughput report
//! — the serving-paper-style end-to-end driver of DESIGN.md.
//!
//! `--devices N` serves over a pool of N device contexts: large GEMMs
//! shard across the pool and the report gains per-device load lines.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};
use mlir_gemm::coordinator::{GemmKey, GemmRequest, Server, ServerConfig};
use mlir_gemm::runtime::{Runtime, Tensor};
use mlir_gemm::sim::DeviceModel;
use mlir_gemm::util::cli::{usage, Args, Spec};
use mlir_gemm::util::prng::Rng;

const SPEC: &[Spec] = &[
    ("devices", true, "device contexts; >1 shards large GEMMs (default 1)"),
    ("plan", true, "plan override: auto|naive|tiled[:MC,KC,NC]|threaded[:MC,KC,NC[,T]]|simd[:ISA[:MC,KC,NC[,T]]]"),
    ("bind", false, "bind every shape's B as a constant weight; half the traffic then ships A (+C) only"),
    ("help", false, "show usage"),
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv, SPEC).map_err(|e| anyhow!("{e}"))?;
    if args.flag("help") {
        println!("{}", usage("gemm_server", "GEMM serving example", SPEC));
        return Ok(());
    }
    let devices = args.get_usize("devices", 1)?;
    let bind = args.flag("bind");
    let plan = args
        .get("plan")
        .map(mlir_gemm::plan::PlanOverride::parse)
        .transpose()?
        .unwrap_or(mlir_gemm::plan::PlanOverride::Auto);

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Arc::new(Runtime::open(&dir)?);
    let device = DeviceModel::rtx3090();
    println!(
        "starting server ({devices} device context(s), plan override {}, \
         profile-guided variant re-ranking on)...",
        plan.name()
    );
    let server = Arc::new(Server::start(
        rt,
        &device,
        ServerConfig {
            workers: 4,
            devices,
            rerank_measured: true,
            plan,
            ..Default::default()
        },
    ));

    let keys: Vec<GemmKey> = server.registry().keys().cloned().collect();
    if keys.is_empty() {
        return Err(anyhow!("no kernels registered; run `make artifacts`"));
    }
    println!("registered shapes:");
    for key in &keys {
        let best = server.registry().best(key).unwrap();
        println!(
            "  {}x{}x{} {} {:<10} -> {} (predicted {:.1} TFLOPs on the modeled 3090)",
            key.m, key.n, key.k,
            key.dtype_acc.name(), key.epilogue,
            best.artifact,
            best.predicted_tflops.unwrap_or(0.0),
        );
    }

    // Model-serving mode: bind every shape's B once; half the traffic
    // below then exercises the weight-bound request form against the
    // bind-time prepacked panels.
    let mut rng = Rng::new(1);
    if bind {
        for key in &keys {
            let b = Tensor::new(vec![key.k, key.n], rng.normal_matrix(key.k, key.n))?;
            server.bind_weights(key, &b)?;
        }
        println!("bound constant B weights for {} shapes", keys.len());
    }

    // Warm every route once so the measured phase excludes XLA compilation.
    for key in &keys {
        let _ = server.call(request(&mut rng, key, false))?;
    }

    // Fire traffic from 4 client threads.
    const PER_CLIENT: usize = 16;
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for cid in 0..4u64 {
        let server = server.clone();
        let keys = keys.clone();
        clients.push(std::thread::spawn(move || -> Result<usize> {
            let mut rng = Rng::new(100 + cid);
            let mut ok = 0;
            let mut pending = Vec::new();
            for i in 0..PER_CLIENT {
                let key = rng.choice(&keys).clone();
                let bound = bind && i % 2 == 0;
                pending.push(server.submit(request(&mut rng, &key, bound)));
            }
            for rx in pending {
                let resp = rx.recv().map_err(|_| anyhow!("server gone"))?;
                if resp.output.is_ok() {
                    ok += 1;
                }
            }
            Ok(ok)
        }));
    }
    let mut total_ok = 0;
    for c in clients {
        total_ok += c.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "\n{} requests in {:.2} s -> {:.1} req/s",
        total_ok,
        wall,
        total_ok as f64 / wall
    );
    let snapshot = server.metrics();
    println!("{}", snapshot.report());
    assert_eq!(total_ok, 4 * PER_CLIENT, "all requests must succeed");
    println!("gemm_server OK");
    Ok(())
}

fn request(rng: &mut Rng, key: &GemmKey, bound: bool) -> GemmRequest {
    let bias = (key.epilogue != "none")
        .then(|| Tensor::new(vec![key.n], rng.normal_matrix(1, key.n)).unwrap());
    let b = (!bound).then(|| {
        Tensor::new(vec![key.k, key.n], rng.normal_matrix(key.k, key.n)).unwrap()
    });
    GemmRequest {
        key: key.clone(),
        a: Tensor::new(vec![key.m, key.k], rng.normal_matrix(key.m, key.k)).unwrap(),
        b,
        c: Tensor::zeros(vec![key.m, key.n]),
        bias,
        use_baseline: false,
        deadline: None,
    }
}
