//! Quickstart: load a pipeline-generated GEMM kernel and execute it.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the three-layer story end to end: the artifact was produced by
//! the tile-IR lowering pipeline (L2/L1, python, build time); here Rust
//! (L3) loads the HLO text, compiles it on the PJRT CPU client, runs it,
//! and checks the numbers against a host reference.

use std::path::PathBuf;

use anyhow::{anyhow, Result};
use mlir_gemm::runtime::{ArtifactKind, Runtime, Tensor};
use mlir_gemm::util::prng::Rng;

fn main() -> Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::open(&dir)?;

    // Pick the fully-optimized generated kernel at 256^3 (mixed precision).
    let meta = rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Generated && a.problem == Some((256, 256, 256)))
        .ok_or_else(|| anyhow!("no 256^3 generated kernel; run `make artifacts`"))?
        .clone();
    println!("kernel:   {}", meta.name);
    let sched = meta.schedule.as_ref().unwrap();
    println!(
        "schedule: tb {:?}, warp {:?}, grid {:?}, {} B shared, {} accumulators/warp",
        sched.tile_tb, sched.tile_warp, sched.grid, sched.smem_bytes,
        sched.accumulators_per_warp
    );

    // Random inputs; C = A @ B + C.
    let (m, n, k) = (256, 256, 256);
    let mut rng = Rng::new(7);
    let a = rng.normal_matrix(m, k);
    let b = rng.normal_matrix(k, n);
    let c = rng.normal_matrix(m, n);
    let out = rt.execute(
        &meta.name,
        &[
            Tensor::new(vec![m, k], a.clone())?,
            Tensor::new(vec![k, n], b.clone())?,
            Tensor::new(vec![m, n], c.clone())?,
        ],
    )?;

    // Spot-check against a host dot product.
    let mut worst = 0f64;
    for &(i, j) in &[(0usize, 0usize), (17, 200), (255, 255), (128, 64)] {
        let want: f64 = (0..k).map(|kk| a[i * k + kk] as f64 * b[kk * n + j] as f64).sum::<f64>()
            + c[i * n + j] as f64;
        let got = out[0].data[i * n + j] as f64;
        worst = worst.max((got - want).abs() / want.abs().max(1.0));
        println!("C[{i:>3},{j:>3}] = {got:>9.4}  (host ref {want:>9.4})");
    }
    println!("worst relative error: {worst:.2e} (f16 inputs, f32 accumulate)");
    assert!(worst < 5e-2);
    println!("quickstart OK");
    Ok(())
}
