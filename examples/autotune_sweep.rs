//! Autotuning walkthrough: the §4 methodology ("we consider different
//! combinations of thread block level tiles and warp level tiles and
//! report the best performing version") over the modeled RTX 3090.
//!
//! Shows the winning tile migrating from small occupancy-friendly tiles at
//! small problem sizes to large reuse-friendly tiles at large ones — the
//! paper's §4.1 observation — and compares each winner to the library
//! heuristic's fixed choice.

use mlir_gemm::autotune;
use mlir_gemm::schedule::Dtype;
use mlir_gemm::sim::{library_tile_choice, simulate_library, DeviceModel};

fn main() {
    let device = DeviceModel::rtx3090();
    for acc in [Dtype::F32, Dtype::F16] {
        println!("### accumulate = {} ###", acc.name());
        println!(
            "{:>6} {:>14} {:>9} {:>14} {:>9} {:>7}",
            "size", "ours tile", "TFLOPs", "lib tile", "TFLOPs", "ratio"
        );
        for size in [1024usize, 2048, 4096, 8192, 11264, 16384] {
            let best = autotune::best(size, size, size, acc, &device).unwrap();
            let lib = simulate_library(size, size, size, acc, &device);
            let (lib_tb, _) = library_tile_choice(size, size, size, acc);
            let tb = best.schedule.tile_tb;
            println!(
                "{:>6} {:>14} {:>9.2} {:>14} {:>9.2} {:>7.3}",
                size,
                format!("{}x{}x{}", tb.0, tb.1, tb.2),
                best.result.tflops,
                format!("{}x{}x{}", lib_tb.0, lib_tb.1, lib_tb.2),
                lib.tflops,
                best.result.tflops / lib.tflops
            );
        }
        println!();
    }
    println!("autotune_sweep OK");
}
