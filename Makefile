# Build/test entry points.  `make verify` mirrors the tier-1 CI check
# exactly; everything else is developer convenience.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: verify verify-scalar build test pytest fuzz check-protocol artifacts artifacts-quick bench-smoke bench-serving plans program-plans plandb lint fmt clean

# Tier-1 verify (ROADMAP.md): must pass from a fresh checkout.
verify:
	$(CARGO) build --release && $(CARGO) test -q

# Tier-1 with the nanokernel backend forced onto the scalar fallback —
# the CI matrix leg that keeps the no-AVX2 path green.
verify-scalar:
	MLIR_GEMM_FORCE_ISA=scalar $(CARGO) build --release && \
	MLIR_GEMM_FORCE_ISA=scalar $(CARGO) test -q

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

pytest:
	$(PYTHON) -m pytest python/tests -q

# Differential fuzz sweep (rust/tests/fuzz_differential.rs): ~200
# deterministic cases proving planned / weight-bound (prepacked) /
# batched / row-sharded execution bit-identical to the naive i-k-j
# reference.  Pinned seed; replay a failure with
# MLIR_GEMM_FUZZ_SEED=<seed> make fuzz.
fuzz:
	$(CARGO) test -q --test fuzz_differential

# Protocol checker (rust/src/check/, DESIGN.md §12–13): exhaustively
# explore every interleaving of the coordinator protocol model at the
# full 3-client × 2-device bound, prove the six invariants non-vacuously
# across the scenario matrix (including the continuous-batching
# admission scenarios: priority tiers, tenant quotas, in-scheduler
# deadline sweeps), then replay a clean shutdown-vs-submit schedule
# against the real server.  The bug-hunt legs re-introduce the PR 5
# stop-flag break (plus the stale-rebind / containment / FIFO-release
# bugs) behind test hooks and demand a counterexample — the stop-flag
# one also replays against the real server to show real stranded jobs.
check-protocol:
	$(CARGO) run --release --bin mlir-gemm -- check-protocol
	$(CARGO) run --release --bin mlir-gemm -- check-protocol --bug stop-flag
	$(CARGO) run --release --bin mlir-gemm -- check-protocol --bug stale-rebind
	$(CARGO) run --release --bin mlir-gemm -- check-protocol --bug no-containment
	$(CARGO) run --release --bin mlir-gemm -- check-protocol --bug fifo-release

# AOT-lower the full artifact set (tprog descriptors + manifest) for the
# Rust runtime's measured subsets and integration tests.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

artifacts-quick:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts --quick

# Run every bench binary in thinned smoke mode so they cannot bit-rot.
# (exec_kernel additionally asserts the auto-compiled plan is never
# slower than naive at 512^3, and — on FMA hardware — that the simd:
# nanokernel row is never slower than the tiled scalar kernel there.)
bench-smoke:
	MLIR_GEMM_SMOKE=1 $(CARGO) bench

# Serving-tier latency bench (rust/benches/serving.rs): lone / paired /
# open-loop zipfian load scenarios through a real server.  Gate (always
# asserted, smoke included): lone and paired p50 beat the old 25 ms
# fixed batching window.  Refresh the committed BENCH_serving.json with
# MLIR_GEMM_RECORD_BASELINE=1 make bench-serving on a labeled runner.
bench-serving:
	MLIR_GEMM_SMOKE=1 $(CARGO) bench --bench serving

# Emit the compiled execution plan for every registry key to
# reports/plans/ (requires built artifacts: `make artifacts`).
plans:
	$(CARGO) run --release --bin mlir-gemm -- plans --artifacts artifacts --out-dir reports

# Emit the graph-level ProgramPlan for every composite-program artifact
# (transformer tprogs) to reports/plans/ (requires `make artifacts`).
program-plans:
	$(CARGO) run --release --bin mlir-gemm -- program-plans --artifacts artifacts --out-dir reports

# Pretty-print the persisted shadow-promotion decisions
# (<artifacts>/reports/plandb.json, written by `serve` with shadow
# tuning on — the default).
plandb:
	$(CARGO) run --release --bin mlir-gemm -- plandb --artifacts artifacts

lint:
	$(CARGO) fmt --check && $(CARGO) clippy -- -D warnings

fmt:
	$(CARGO) fmt

clean:
	$(CARGO) clean
	rm -rf artifacts reports python/**/__pycache__
